//! Design partitioner (paper §2.2 item 1).
//!
//! CircuitNet partitions each design evenly into graphs of roughly 10k
//! nodes. Our generator produces partitions directly, but this module also
//! provides the inverse operation — splitting one large heterograph into
//! balanced partitions — so the pipeline matches the paper's preprocessing
//! and so tests can check conservation invariants.

use super::csr::Csr;
use super::hetero::HeteroGraph;

/// Stable node remapping of one partition back to its parent graph:
/// `cell_ids[i]` / `net_ids[j]` are the parent indices of local cell `i` /
/// local net `j`. Cell ids are contiguous ranges (range partitioning) and
/// net ids are in first-touch order, both fully determined by the parent
/// graph and the partition count — the fleet relies on this stability to
/// reduce per-subgraph results deterministically.
#[derive(Clone, Debug)]
pub struct PartitionMap {
    pub cell_ids: Vec<usize>,
    pub net_ids: Vec<usize>,
}

/// Split a heterograph into `parts` cell-contiguous partitions. Cells are
/// range-partitioned; each partition keeps the nets that touch its cells.
/// Edges crossing partition boundaries are dropped (the paper's partitions
/// are likewise independent graphs).
pub fn partition(g: &HeteroGraph, parts: usize) -> Vec<HeteroGraph> {
    partition_with_map(g, parts).into_iter().map(|(sub, _)| sub).collect()
}

/// [`partition`], additionally returning each subgraph's [`PartitionMap`]
/// so per-subgraph outputs (predictions, gradients) can be scattered back
/// to parent node indices.
pub fn partition_with_map(g: &HeteroGraph, parts: usize) -> Vec<(HeteroGraph, PartitionMap)> {
    assert!(parts >= 1);
    let per = g.n_cells.div_ceil(parts);
    let mut out = Vec::with_capacity(parts);
    for p in 0..parts {
        let cell_lo = p * per;
        let cell_hi = ((p + 1) * per).min(g.n_cells);
        if cell_lo >= cell_hi {
            break;
        }
        let n_cells = cell_hi - cell_lo;

        // near: keep edges with both endpoints inside.
        let mut near_t = Vec::new();
        for r in cell_lo..cell_hi {
            for q in g.near.row_range(r) {
                let c = g.near.indices[q] as usize;
                if (cell_lo..cell_hi).contains(&c) {
                    near_t.push((r - cell_lo, c - cell_lo, g.near.values[q]));
                }
            }
        }

        // Nets touched by this partition's cells (via pins: rows = nets).
        let mut net_map = vec![usize::MAX; g.n_nets];
        let mut n_nets = 0usize;
        let mut pins_t = Vec::new();
        for net in 0..g.n_nets {
            for q in g.pins.row_range(net) {
                let cell = g.pins.indices[q] as usize;
                if (cell_lo..cell_hi).contains(&cell) {
                    if net_map[net] == usize::MAX {
                        net_map[net] = n_nets;
                        n_nets += 1;
                    }
                    pins_t.push((net_map[net], cell - cell_lo, g.pins.values[q]));
                }
            }
        }

        let near = Csr::from_triplets(n_cells, n_cells, &near_t);
        let pins = Csr::from_triplets(n_nets, n_cells, &pins_t);
        let pinned = pins.transpose();

        // Feature/label slices.
        let cell_idx: Vec<usize> = (cell_lo..cell_hi).collect();
        let mut net_idx = vec![0usize; n_nets];
        for (old, &new) in net_map.iter().enumerate() {
            if new != usize::MAX {
                net_idx[new] = old;
            }
        }
        out.push((
            HeteroGraph {
                id: p,
                n_cells,
                n_nets,
                near,
                pins,
                pinned,
                x_cell: g.x_cell.gather_rows(&cell_idx),
                x_net: g.x_net.gather_rows(&net_idx),
                y_cell: g.y_cell.gather_rows(&cell_idx),
            },
            PartitionMap { cell_ids: cell_idx, net_ids: net_idx },
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;
    use crate::util::rng::Rng;

    fn random_graph(n_cells: usize, n_nets: usize, seed: u64) -> HeteroGraph {
        let mut rng = Rng::new(seed);
        let mut near_t = Vec::new();
        for r in 0..n_cells {
            for _ in 0..3 {
                let c = rng.below(n_cells);
                if c != r {
                    near_t.push((r, c, 1.0));
                }
            }
        }
        let mut pins_t = Vec::new();
        for net in 0..n_nets {
            for _ in 0..2 {
                pins_t.push((net, rng.below(n_cells), 1.0));
            }
        }
        let near = Csr::from_triplets(n_cells, n_cells, &near_t);
        let pins = Csr::from_triplets(n_nets, n_cells, &pins_t);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 0,
            n_cells,
            n_nets,
            near,
            pins,
            pinned,
            x_cell: Matrix::randn(n_cells, 4, 1.0, &mut rng),
            x_net: Matrix::randn(n_nets, 4, 1.0, &mut rng),
            y_cell: Matrix::randn(n_cells, 1, 1.0, &mut rng),
        }
    }

    #[test]
    fn partitions_are_valid_and_cover_cells() {
        let g = random_graph(100, 40, 5);
        let parts = partition(&g, 4);
        assert_eq!(parts.len(), 4);
        let total: usize = parts.iter().map(|p| p.n_cells).sum();
        assert_eq!(total, 100);
        for p in &parts {
            p.validate().unwrap();
        }
    }

    #[test]
    fn partition_preserves_features() {
        let g = random_graph(50, 20, 6);
        let parts = partition(&g, 2);
        // First cell of second partition is cell 25 of the original.
        assert_eq!(parts[1].x_cell.row(0), g.x_cell.row(25));
        assert_eq!(parts[1].y_cell.row(0), g.y_cell.row(25));
    }

    #[test]
    fn single_partition_keeps_all_near_edges() {
        let g = random_graph(30, 10, 7);
        let parts = partition(&g, 1);
        assert_eq!(parts.len(), 1);
        assert_eq!(parts[0].near.nnz(), g.near.nnz());
        assert_eq!(parts[0].pins.nnz(), g.pins.nnz());
    }

    #[test]
    fn cross_edges_dropped_monotonically() {
        let g = random_graph(60, 25, 8);
        let p2: usize = partition(&g, 2).iter().map(|p| p.near.nnz()).sum();
        let p6: usize = partition(&g, 6).iter().map(|p| p.near.nnz()).sum();
        assert!(p2 <= g.near.nnz());
        assert!(p6 <= p2);
    }

    #[test]
    fn maps_are_stable_and_consistent_with_slices() {
        let g = random_graph(60, 22, 10);
        let a = partition_with_map(&g, 3);
        let b = partition_with_map(&g, 3);
        for ((pa, ma), (pb, mb)) in a.iter().zip(&b) {
            assert_eq!(ma.cell_ids, mb.cell_ids, "cell remap must be deterministic");
            assert_eq!(ma.net_ids, mb.net_ids, "net remap must be deterministic");
            assert_eq!(pa.adjacency_hash(), pb.adjacency_hash());
        }
        for (sub, map) in &a {
            assert_eq!(map.cell_ids.len(), sub.n_cells);
            assert_eq!(map.net_ids.len(), sub.n_nets);
            for (local, &parent) in map.cell_ids.iter().enumerate() {
                assert_eq!(sub.x_cell.row(local), g.x_cell.row(parent));
            }
            for (local, &parent) in map.net_ids.iter().enumerate() {
                assert_eq!(sub.x_net.row(local), g.x_net.row(parent));
            }
        }
        // Cell ranges are contiguous and cover the parent exactly once.
        let all: Vec<usize> = a.iter().flat_map(|(_, m)| m.cell_ids.clone()).collect();
        assert_eq!(all, (0..60).collect::<Vec<_>>());
    }

    #[test]
    fn nets_not_duplicated_within_partition() {
        let g = random_graph(40, 15, 9);
        for p in partition(&g, 3) {
            // each partition's nets have at least one pin
            for net in 0..p.n_nets {
                assert!(p.pins.degree(net) >= 1);
            }
        }
    }
}
