//! Degree statistics and workload-imbalance metrics.
//!
//! Regenerates Fig. 4 (degree histograms per edge type) and quantifies the
//! "evil row" effect of §2.3: `imbalance = max_deg / avg_deg`, the factor by
//! which a static row-per-warp SpMM tail-lags.

use super::csr::Csr;
use super::hetero::{EdgeType, HeteroGraph};

/// Histogram of node degrees with fixed-width bins.
#[derive(Clone, Debug)]
pub struct DegreeHistogram {
    pub bin_width: usize,
    /// counts[b] = number of rows with degree in [b*w, (b+1)*w).
    pub counts: Vec<usize>,
    pub max_degree: usize,
    pub avg_degree: f64,
    pub rows: usize,
}

impl DegreeHistogram {
    pub fn of(adj: &Csr, bin_width: usize) -> DegreeHistogram {
        assert!(bin_width > 0);
        let max_degree = adj.max_degree();
        let nbins = max_degree / bin_width + 1;
        let mut counts = vec![0usize; nbins];
        for r in 0..adj.rows {
            counts[adj.degree(r) / bin_width] += 1;
        }
        DegreeHistogram {
            bin_width,
            counts,
            max_degree,
            avg_degree: adj.avg_degree(),
            rows: adj.rows,
        }
    }

    /// Degree value with the most rows (mode bin center) — paper Fig. 4
    /// describes `near` peaking around 50 and pins/pinned at 3–4.
    pub fn mode_degree(&self) -> usize {
        let b = self
            .counts
            .iter()
            .enumerate()
            .max_by_key(|&(_, c)| *c)
            .map(|(b, _)| b)
            .unwrap_or(0);
        b * self.bin_width + self.bin_width / 2
    }

    /// Fraction of rows with degree ≥ `d`.
    pub fn tail_fraction(&self, d: usize) -> f64 {
        if self.rows == 0 {
            return 0.0;
        }
        let from_bin = d / self.bin_width;
        let tail: usize = self.counts.iter().skip(from_bin).sum();
        tail as f64 / self.rows as f64
    }

    /// ASCII sparkline of the histogram (bench output).
    pub fn sparkline(&self, width: usize) -> String {
        if self.counts.is_empty() {
            return String::new();
        }
        const BARS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
        let step = self.counts.len().div_ceil(width.max(1));
        let agg: Vec<usize> = self
            .counts
            .chunks(step.max(1))
            .map(|c| c.iter().sum::<usize>())
            .collect();
        let max = *agg.iter().max().unwrap_or(&1) as f64;
        agg.iter()
            .map(|&c| {
                let lvl = ((c as f64 / max.max(1.0)) * 7.0).round() as usize;
                BARS[lvl.min(7)]
            })
            .collect()
    }
}

/// Workload-imbalance metrics for an adjacency matrix (§2.3: W_i = |N(i)|·D;
/// P_max throttled by max_i |N(i)|).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ImbalanceStats {
    pub max_degree: usize,
    pub avg_degree: f64,
    /// max/avg — 1.0 means perfectly balanced rows.
    pub imbalance: f64,
    /// Coefficient of variation of row degrees.
    pub cv: f64,
}

impl ImbalanceStats {
    pub fn of(adj: &Csr) -> ImbalanceStats {
        let degs: Vec<f64> = (0..adj.rows).map(|r| adj.degree(r) as f64).collect();
        let avg = if degs.is_empty() { 0.0 } else { degs.iter().sum::<f64>() / degs.len() as f64 };
        let var = if degs.is_empty() {
            0.0
        } else {
            degs.iter().map(|d| (d - avg) * (d - avg)).sum::<f64>() / degs.len() as f64
        };
        ImbalanceStats {
            max_degree: adj.max_degree(),
            avg_degree: avg,
            imbalance: if avg > 0.0 { adj.max_degree() as f64 / avg } else { 0.0 },
            cv: if avg > 0.0 { var.sqrt() / avg } else { 0.0 },
        }
    }
}

/// Fig. 4 bundle: a histogram per edge type of a heterograph.
pub fn degree_report(g: &HeteroGraph, bin_width: usize) -> Vec<(EdgeType, DegreeHistogram)> {
    EdgeType::ALL
        .iter()
        .map(|&e| (e, DegreeHistogram::of(g.adj(e), bin_width)))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn skewed() -> Csr {
        // Row 0 has 8 neighbors, rows 1..=7 have 1 each: evil row 0.
        let mut t = vec![];
        for c in 0..8 {
            t.push((0usize, c as usize, 1.0));
        }
        for r in 1..8 {
            t.push((r, 0, 1.0));
        }
        Csr::from_triplets(8, 8, &t)
    }

    #[test]
    fn histogram_counts_all_rows() {
        let h = DegreeHistogram::of(&skewed(), 1);
        assert_eq!(h.counts.iter().sum::<usize>(), 8);
        assert_eq!(h.max_degree, 8);
        assert_eq!(h.counts[1], 7); // seven rows of degree 1
        assert_eq!(h.counts[8], 1); // one evil row
    }

    #[test]
    fn mode_and_tail() {
        let h = DegreeHistogram::of(&skewed(), 1);
        assert_eq!(h.mode_degree(), 1);
        assert!((h.tail_fraction(8) - 1.0 / 8.0).abs() < 1e-12);
        assert!((h.tail_fraction(0) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn imbalance_detects_evil_rows() {
        let s = ImbalanceStats::of(&skewed());
        assert_eq!(s.max_degree, 8);
        assert!((s.avg_degree - 15.0 / 8.0).abs() < 1e-12);
        assert!(s.imbalance > 4.0);
        assert!(s.cv > 1.0);
    }

    #[test]
    fn uniform_graph_is_balanced() {
        let t: Vec<_> = (0..8).map(|r| (r, (r + 1) % 8, 1.0)).collect();
        let s = ImbalanceStats::of(&Csr::from_triplets(8, 8, &t));
        assert!((s.imbalance - 1.0).abs() < 1e-12);
        assert_eq!(s.cv, 0.0);
    }

    #[test]
    fn sparkline_renders() {
        let h = DegreeHistogram::of(&skewed(), 1);
        let s = h.sparkline(10);
        assert!(!s.is_empty());
    }
}
