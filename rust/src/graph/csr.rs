//! Compressed sparse row / column adjacency matrices.

pub(crate) const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// One FNV-1a mixing step — shared with [`super::hetero`]'s composite
/// adjacency hash so the cache-key scheme lives in one place.
#[inline]
pub(crate) fn fnv_mix(h: u64, x: u64) -> u64 {
    (h ^ x).wrapping_mul(FNV_PRIME)
}

/// CSR sparse matrix (`rows × cols`, f32 values).
///
/// `indptr.len() == rows + 1`; row `r`'s neighbors are
/// `indices[indptr[r]..indptr[r+1]]` with matching `values`.
#[derive(Clone, Debug, PartialEq)]
pub struct Csr {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

/// CSC sparse matrix — same fields, column-major. Used by the DR-SpMM
/// backward kernel (paper Alg. 2 stage 1 transposes to CSC).
#[derive(Clone, Debug, PartialEq)]
pub struct Csc {
    pub rows: usize,
    pub cols: usize,
    pub indptr: Vec<usize>,
    /// Row indices per column.
    pub indices: Vec<u32>,
    pub values: Vec<f32>,
}

impl Csr {
    /// Build from (row, col, value) triplets; duplicates are summed.
    pub fn from_triplets(
        rows: usize,
        cols: usize,
        triplets: &[(usize, usize, f32)],
    ) -> Csr {
        let mut counts = vec![0usize; rows];
        for &(r, c, _) in triplets {
            assert!(r < rows && c < cols, "triplet ({r},{c}) out of bounds");
            counts[r] += 1;
        }
        let mut indptr = vec![0usize; rows + 1];
        for r in 0..rows {
            indptr[r + 1] = indptr[r] + counts[r];
        }
        let nnz = indptr[rows];
        let mut indices = vec![0u32; nnz];
        let mut values = vec![0f32; nnz];
        let mut cursor = indptr.clone();
        for &(r, c, v) in triplets {
            let p = cursor[r];
            indices[p] = c as u32;
            values[p] = v;
            cursor[r] += 1;
        }
        let mut m = Csr { rows, cols, indptr, indices, values };
        m.sort_and_dedup();
        m
    }

    /// Sort each row by column index and merge duplicate entries into
    /// canonical form (see [`push_canonical_row`]).
    fn sort_and_dedup(&mut self) {
        let mut new_indptr = vec![0usize; self.rows + 1];
        let mut new_indices = Vec::with_capacity(self.indices.len());
        let mut new_values = Vec::with_capacity(self.values.len());
        for r in 0..self.rows {
            let (s, e) = (self.indptr[r], self.indptr[r + 1]);
            let mut row: Vec<(u32, f32)> = self.indices[s..e]
                .iter()
                .copied()
                .zip(self.values[s..e].iter().copied())
                .collect();
            row.sort_by_key(|&(c, _)| c);
            push_canonical_row(&row, &mut new_indices, &mut new_values);
            new_indptr[r + 1] = new_indices.len();
        }
        self.indptr = new_indptr;
        self.indices = new_indices;
        self.values = new_values;
    }

    /// Whether this matrix is in canonical form: every row's columns
    /// strictly ascending (sorted, no duplicates) and no stored value
    /// exactly `±0.0`. Every constructor in the crate produces canonical
    /// matrices; [`crate::graph::delta`] relies on the invariant to make
    /// patched graphs bit-identical to from-scratch rebuilds.
    pub fn is_canonical(&self) -> bool {
        for r in 0..self.rows {
            let range = self.row_range(r);
            if !self.indices[range.clone()].windows(2).all(|w| w[0] < w[1]) {
                return false;
            }
            if self.values[range].iter().any(|&v| v == 0.0) {
                return false;
            }
        }
        true
    }

    /// Value stored at `(r, c)`, if any — binary search over the row's
    /// sorted column indices (canonical form), O(log degree).
    pub fn get(&self, r: usize, c: usize) -> Option<f32> {
        let range = self.row_range(r);
        let cols = &self.indices[range.clone()];
        cols.binary_search(&(c as u32)).ok().map(|i| self.values[range.start + i])
    }

    /// The matrix as `(row, col, value)` triplets in storage order.
    /// Feeding them back through [`Csr::from_triplets`] reproduces the
    /// matrix bit-identically (canonical form is a fixed point).
    pub fn to_triplets(&self) -> Vec<(usize, usize, f32)> {
        let mut out = Vec::with_capacity(self.nnz());
        for r in 0..self.rows {
            for p in self.row_range(r) {
                out.push((r, self.indices[p] as usize, self.values[p]));
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    #[inline]
    pub fn row_range(&self, r: usize) -> std::ops::Range<usize> {
        self.indptr[r]..self.indptr[r + 1]
    }

    #[inline]
    pub fn degree(&self, r: usize) -> usize {
        self.indptr[r + 1] - self.indptr[r]
    }

    pub fn max_degree(&self) -> usize {
        (0..self.rows).map(|r| self.degree(r)).max().unwrap_or(0)
    }

    pub fn avg_degree(&self) -> f64 {
        if self.rows == 0 {
            0.0
        } else {
            self.nnz() as f64 / self.rows as f64
        }
    }

    /// Convert to CSC (i.e. transpose the storage order, keeping the logical
    /// matrix identical).
    pub fn to_csc(&self) -> Csc {
        let mut counts = vec![0usize; self.cols];
        for &c in &self.indices {
            counts[c as usize] += 1;
        }
        let mut indptr = vec![0usize; self.cols + 1];
        for c in 0..self.cols {
            indptr[c + 1] = indptr[c] + counts[c];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        let mut cursor = indptr.clone();
        for r in 0..self.rows {
            for p in self.row_range(r) {
                let c = self.indices[p] as usize;
                let q = cursor[c];
                indices[q] = r as u32;
                values[q] = self.values[p];
                cursor[c] += 1;
            }
        }
        Csc { rows: self.rows, cols: self.cols, indptr, indices, values }
    }

    /// Logical transpose: an `cols × rows` CSR (used for pins ↔ pinned).
    pub fn transpose(&self) -> Csr {
        let csc = self.to_csc();
        Csr {
            rows: self.cols,
            cols: self.rows,
            indptr: csc.indptr,
            indices: csc.indices,
            values: csc.values,
        }
    }

    /// Dense representation (tests only; O(rows·cols)).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.rows * self.cols];
        for r in 0..self.rows {
            for p in self.row_range(r) {
                out[r * self.cols + self.indices[p] as usize] = self.values[p];
            }
        }
        out
    }

    /// Row-normalise values (mean aggregation: value = 1/deg). Rows with no
    /// neighbors stay empty.
    pub fn normalize_rows(&mut self) {
        for r in 0..self.rows {
            let range = self.row_range(r);
            let deg = range.len();
            if deg == 0 {
                continue;
            }
            let inv = 1.0 / deg as f32;
            for p in range {
                self.values[p] = inv;
            }
        }
    }

    /// Symmetric GCN normalisation value(i,j) = 1/sqrt(deg_out(i)·deg_in(j))
    /// — only meaningful for square matrices.
    pub fn normalize_gcn(&mut self) {
        assert_eq!(self.rows, self.cols, "GCN normalisation needs a square matrix");
        let mut in_deg = vec![0usize; self.cols];
        for &c in &self.indices {
            in_deg[c as usize] += 1;
        }
        for r in 0..self.rows {
            let deg_r = self.degree(r).max(1) as f32;
            for p in self.row_range(r) {
                let deg_c = in_deg[self.indices[p] as usize].max(1) as f32;
                self.values[p] = 1.0 / (deg_r.sqrt() * deg_c.sqrt());
            }
        }
    }

    /// 64-bit FNV-1a content hash over the full matrix content: shape,
    /// row pointers, column indices and value bits. Two matrices hash equal
    /// iff they are logically identical (up to the 2⁻⁶⁴ collision odds), so
    /// this is the key the fleet's shared plan cache uses — any mutation of
    /// an edge, a weight or the shape changes the hash.
    pub fn content_hash(&self) -> u64 {
        let mut h = fnv_mix(FNV_OFFSET, self.rows as u64);
        h = fnv_mix(h, self.cols as u64);
        for &p in &self.indptr {
            h = fnv_mix(h, p as u64);
        }
        for &c in &self.indices {
            h = fnv_mix(h, c as u64);
        }
        for &v in &self.values {
            h = fnv_mix(h, v.to_bits() as u64);
        }
        h
    }

    /// Structural equality with another matrix's transpose — validates the
    /// paper's pins = pinnedᵀ invariant without allocating a transpose.
    ///
    /// Genuinely allocation-free (a PR-7 doc claim this now actually
    /// honors): every entry `(r, c)` of `self` is looked up at `(c, r)` in
    /// `other` by binary search. With both matrices canonical (unique
    /// columns per row — every in-crate constructor guarantees it), equal
    /// nnz plus all probes matching is a bijection proof: distinct `self`
    /// entries probe distinct `other` keys, so `nnz` successful probes
    /// cover all of `other`. O(nnz · log degree), zero heap traffic. The
    /// `transpose()`-based tests remain the reference oracle.
    pub fn is_transpose_of(&self, other: &Csr) -> bool {
        if self.rows != other.cols || self.cols != other.rows || self.nnz() != other.nnz() {
            return false;
        }
        for r in 0..self.rows {
            for p in self.row_range(r) {
                let c = self.indices[p] as usize;
                if other.get(c, r) != Some(self.values[p]) {
                    return false;
                }
            }
        }
        true
    }
}

/// Append one sorted row's canonical form to `indices`/`values`: duplicate
/// columns are summed and entries whose **merged** value is exactly `±0.0`
/// are dropped. This is the single canonicalization point shared by
/// [`Csr::from_triplets`] and [`crate::graph::delta`]: any triplet list
/// maps to exactly one stored form, so an ECO add-then-remove round-trip
/// restores the original `content_hash` bit for bit. (Consequence: a CSR
/// cannot hold an explicit zero-weight edge — "weight 0" *is* "no edge".)
/// `row` must already be sorted by column.
pub(crate) fn push_canonical_row(
    row: &[(u32, f32)],
    indices: &mut Vec<u32>,
    values: &mut Vec<f32>,
) {
    debug_assert!(row.windows(2).all(|w| w[0].0 <= w[1].0), "row must be sorted");
    let mut i = 0;
    while i < row.len() {
        let (c, mut v) = row[i];
        let mut j = i + 1;
        while j < row.len() && row[j].0 == c {
            v += row[j].1;
            j += 1;
        }
        if v != 0.0 {
            indices.push(c);
            values.push(v);
        }
        i = j;
    }
}

impl Csc {
    #[inline]
    pub fn col_range(&self, c: usize) -> std::ops::Range<usize> {
        self.indptr[c]..self.indptr[c + 1]
    }

    pub fn nnz(&self) -> usize {
        self.indices.len()
    }

    /// Back to CSR (round-trip used in tests).
    pub fn to_csr(&self) -> Csr {
        let mut counts = vec![0usize; self.rows];
        for &r in &self.indices {
            counts[r as usize] += 1;
        }
        let mut indptr = vec![0usize; self.rows + 1];
        for r in 0..self.rows {
            indptr[r + 1] = indptr[r] + counts[r];
        }
        let mut indices = vec![0u32; self.nnz()];
        let mut values = vec![0f32; self.nnz()];
        let mut cursor = indptr.clone();
        for c in 0..self.cols {
            for p in self.col_range(c) {
                let r = self.indices[p] as usize;
                let q = cursor[r];
                indices[q] = c as u32;
                values[q] = self.values[p];
                cursor[r] += 1;
            }
        }
        Csr { rows: self.rows, cols: self.cols, indptr, indices, values }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr {
        // [[0, 1, 0],
        //  [2, 0, 3],
        //  [0, 0, 0],
        //  [4, 5, 6]]
        Csr::from_triplets(
            4,
            3,
            &[(0, 1, 1.0), (1, 0, 2.0), (1, 2, 3.0), (3, 0, 4.0), (3, 1, 5.0), (3, 2, 6.0)],
        )
    }

    #[test]
    fn from_triplets_basic() {
        let m = sample();
        assert_eq!(m.nnz(), 6);
        assert_eq!(m.degree(0), 1);
        assert_eq!(m.degree(2), 0);
        assert_eq!(m.degree(3), 3);
        assert_eq!(m.max_degree(), 3);
        assert!((m.avg_degree() - 1.5).abs() < 1e-12);
    }

    #[test]
    fn duplicates_summed() {
        let m = Csr::from_triplets(1, 2, &[(0, 1, 1.0), (0, 1, 2.5)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.values, vec![3.5]);
    }

    #[test]
    fn duplicates_cancelling_to_zero_are_dropped() {
        // The PR-8 canonical-form fix: a merged sum of exactly 0.0 removes
        // the entry, so "edge added then removed" hashes like "never there".
        let m = Csr::from_triplets(2, 3, &[(0, 1, 1.5), (0, 1, -1.5), (1, 2, 2.0)]);
        assert_eq!(m.nnz(), 1);
        assert_eq!(m.indices, vec![2]);
        assert!(m.is_canonical());
        let clean = Csr::from_triplets(2, 3, &[(1, 2, 2.0)]);
        assert_eq!(m, clean);
        assert_eq!(m.content_hash(), clean.content_hash());
        // An explicit-zero triplet is likewise unrepresentable.
        let z = Csr::from_triplets(1, 2, &[(0, 0, 0.0)]);
        assert_eq!(z.nnz(), 0);
        // -0.0 counts as zero too (f32 == semantics).
        let nz = Csr::from_triplets(1, 2, &[(0, 0, -0.0)]);
        assert_eq!(nz.nnz(), 0);
    }

    #[test]
    fn canonical_form_is_a_from_triplets_fixed_point() {
        let m = sample();
        assert!(m.is_canonical());
        let rebuilt = Csr::from_triplets(m.rows, m.cols, &m.to_triplets());
        assert_eq!(m, rebuilt);
        assert_eq!(m.content_hash(), rebuilt.content_hash());
        let mut broken = m.clone();
        broken.values[0] = 0.0;
        assert!(!broken.is_canonical());
    }

    #[test]
    fn get_finds_exactly_the_stored_entries() {
        let m = sample();
        assert_eq!(m.get(0, 1), Some(1.0));
        assert_eq!(m.get(3, 2), Some(6.0));
        assert_eq!(m.get(0, 0), None);
        assert_eq!(m.get(2, 1), None);
    }

    #[test]
    fn rows_sorted() {
        let m = Csr::from_triplets(1, 5, &[(0, 4, 1.0), (0, 1, 2.0), (0, 3, 3.0)]);
        assert_eq!(m.indices, vec![1, 3, 4]);
        assert_eq!(m.values, vec![2.0, 3.0, 1.0]);
    }

    #[test]
    fn dense_matches() {
        let m = sample();
        let d = m.to_dense();
        assert_eq!(d[0 * 3 + 1], 1.0);
        assert_eq!(d[1 * 3 + 0], 2.0);
        assert_eq!(d[3 * 3 + 2], 6.0);
        assert_eq!(d.iter().filter(|&&x| x != 0.0).count(), 6);
    }

    #[test]
    fn csc_round_trip() {
        let m = sample();
        let back = m.to_csc().to_csr();
        assert_eq!(m, back);
    }

    #[test]
    fn transpose_involution_and_dense_agreement() {
        let m = sample();
        let t = m.transpose();
        assert_eq!(t.rows, 3);
        assert_eq!(t.cols, 4);
        assert_eq!(m.transpose().transpose(), m);
        let d = m.to_dense();
        let dt = t.to_dense();
        for r in 0..4 {
            for c in 0..3 {
                assert_eq!(d[r * 3 + c], dt[c * 4 + r]);
            }
        }
        assert!(t.is_transpose_of(&m));
        assert!(m.is_transpose_of(&t));
    }

    /// The allocation-free `is_transpose_of` against the materialising
    /// oracle (`transpose()` + array equality), positive and negative
    /// cases over random matrices.
    #[test]
    fn is_transpose_of_matches_transpose_oracle() {
        let mut rng = crate::util::rng::Rng::new(42);
        for trial in 0..40 {
            let rows = rng.range(1, 12);
            let cols = rng.range(1, 12);
            let mut t = Vec::new();
            for r in 0..rows {
                for _ in 0..rng.range(0, 5) {
                    t.push((r, rng.below(cols), rng.uniform(-2.0, 2.0)));
                }
            }
            let m = Csr::from_triplets(rows, cols, &t);
            let mut other = m.transpose();
            // Half the trials perturb `other` somewhere.
            if trial % 2 == 1 && other.nnz() > 0 {
                let p = rng.below(other.nnz());
                if rng.next_u32() & 1 == 0 {
                    other.values[p] += 0.25;
                } else {
                    // Move an entry to a (possibly) different column.
                    let row = (0..other.rows).find(|&r| other.row_range(r).contains(&p)).unwrap();
                    let tr = Csr::from_triplets(
                        other.rows,
                        other.cols,
                        &other
                            .to_triplets()
                            .into_iter()
                            .map(|(r, c, v)| {
                                if r == row && c == other.indices[p] as usize {
                                    (r, (c + 1) % other.cols, v)
                                } else {
                                    (r, c, v)
                                }
                            })
                            .collect::<Vec<_>>(),
                    );
                    other = tr;
                }
            }
            let oracle = {
                let tt = other.transpose();
                m.rows == tt.rows
                    && m.cols == tt.cols
                    && m.indptr == tt.indptr
                    && m.indices == tt.indices
                    && m.values == tt.values
            };
            assert_eq!(m.is_transpose_of(&other), oracle, "trial {trial}");
        }
        // Shape mismatches short-circuit to false.
        let a = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]);
        let b = Csr::from_triplets(2, 3, &[(0, 0, 1.0)]);
        assert!(!a.is_transpose_of(&b));
    }

    #[test]
    fn row_normalise_mean() {
        let mut m = sample();
        m.normalize_rows();
        for p in m.row_range(3) {
            assert!((m.values[p] - 1.0 / 3.0).abs() < 1e-7);
        }
        assert_eq!(m.degree(2), 0); // empty row untouched
    }

    #[test]
    fn gcn_normalise_square() {
        let mut m = Csr::from_triplets(2, 2, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0)]);
        m.normalize_gcn();
        // deg_out(0)=2, deg_in(0)=1 -> 1/sqrt(2)
        let d = m.to_dense();
        assert!((d[0] - 1.0 / (2f32).sqrt()).abs() < 1e-6);
        // deg_out(1)=1, deg_in(1)=2 -> 1/sqrt(2)
        assert!((d[3] - 1.0 / (2f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn oob_triplet_panics() {
        Csr::from_triplets(2, 2, &[(2, 0, 1.0)]);
    }

    #[test]
    fn content_hash_stable_for_equal_matrices() {
        assert_eq!(sample().content_hash(), sample().content_hash());
    }

    #[test]
    fn content_hash_changes_on_any_mutation() {
        let base = sample();
        let h0 = base.content_hash();
        // Changed value.
        let mut m = base.clone();
        m.values[0] += 1.0;
        assert_ne!(m.content_hash(), h0);
        // Extra edge.
        let mut t = vec![
            (0, 1, 1.0),
            (1, 0, 2.0),
            (1, 2, 3.0),
            (3, 0, 4.0),
            (3, 1, 5.0),
            (3, 2, 6.0),
        ];
        t.push((2, 2, 1.0));
        let m = Csr::from_triplets(4, 3, &t);
        assert_ne!(m.content_hash(), h0);
        // Same nnz, different shape.
        let mut m = base.clone();
        m.cols = 4;
        assert_ne!(m.content_hash(), h0);
    }
}
