//! Circuit-graph substrate: sparse formats and the heterogeneous graph.
//!
//! * [`Csr`] / [`Csc`] — compressed sparse row/column adjacency with
//!   round-trip conversion (the backward pass traverses CSC, paper Alg. 2).
//! * [`Cbsr`] — Compressed *Balanced* Sparse Row: the output format of
//!   D-ReLU (exactly `k` surviving values + column indices per row).
//! * [`HeteroGraph`] — typed nodes (`cell`, `net`) and typed edges
//!   (`near`: cell→cell, `pins`: cell→net, `pinned`: net→cell), with the
//!   pins = pinnedᵀ invariant from §2.2 of the paper.
//! * [`stats`] — degree histograms (Fig. 4) and workload-imbalance metrics
//!   (the "evil row" factor of §2.3).
//! * [`partition`] — splits a design into ~10k-node partitions (§2.2 item 1),
//!   with stable node remapping ([`PartitionMap`]) for the fleet layer.

//! * [`delta`] — incremental ECO patches ([`DeltaPatch`]): edit a graph in
//!   place of a rebuild, bit-identical to the from-scratch result, and
//!   route parent ECOs onto partitions so only touched subgraphs restage.

pub mod cbsr;
pub mod csr;
pub mod delta;
pub mod hetero;
pub mod partition;
pub mod stats;

pub use cbsr::Cbsr;
pub use csr::{Csc, Csr};
pub use delta::{apply as apply_delta, DeltaPatch, EdgeOp};
pub use hetero::{EdgeType, HeteroGraph, NodeType};
pub use partition::{
    cut_partition, partition_with_map, route_patch, PartitionMap, RoutedDelta, RoutedPatch,
};
