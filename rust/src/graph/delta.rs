//! Incremental ECO deltas: patch a [`HeteroGraph`] in place of a rebuild.
//!
//! Real EDA flows never regenerate a netlist — they apply small
//! engineering change orders (ECOs) to a design that is 99.9% unchanged
//! (ROADMAP item 3). A [`DeltaPatch`] captures one such ECO: edge
//! add/remove/reweight ops per [`EdgeType`] plus node-feature and label
//! updates. [`apply`] produces a patched graph that is **bit-identical**
//! (same `content_hash`/`adjacency_hash`, same CSR arrays) to rebuilding
//! the graph from the patched triplet list with [`Csr::from_triplets`] —
//! the property everything downstream leans on: the engine's incremental
//! plan repair ([`crate::engine::repair`]) diffs old vs new normalized
//! rows, and the fleet's ECO restage ([`crate::fleet::eco`]) reuses the
//! plan-cache entries of untouched subgraphs.
//!
//! Bit-identity holds because both paths share one canonicalization point
//! ([`super::csr::push_canonical_row`]): rows sorted by column, duplicates
//! summed, exact-zero merged values dropped. A consequence worth stating:
//! a zero weight *is* edge absence, so `Reweight` to `0.0` removes the
//! edge and `Add` with weight `0.0` is a no-op — exactly what a
//! from-scratch rebuild of the same triplets would store.
//!
//! `Pins`/`Pinned` are one logical relation stored twice (pins = pinnedᵀ,
//! §2.2). Ops may be expressed against either type; the patch normalizes
//! them into pins coordinates `(net, cell)` and [`apply`] edits **both**
//! matrices, so the transpose invariant survives by construction (and is
//! re-checked by `validate`). See `docs/DELTA.md`.

use super::csr::{push_canonical_row, Csr};
use super::hetero::{EdgeType, HeteroGraph};

/// One edge mutation in the destination-major `(row, col)` frame of its
/// edge type's adjacency (`near`: both cells; `pins`: row = net,
/// col = cell; `pinned`: row = cell, col = net).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EdgeOp {
    /// Insert an absent edge. Errors if the edge exists (use `Reweight`);
    /// a weight of exactly `0.0` is a no-op (canonical form holds no
    /// explicit zeros).
    Add { row: usize, col: usize, w: f32 },
    /// Delete an existing edge. Errors if absent.
    Remove { row: usize, col: usize },
    /// Replace an existing edge's weight. Errors if absent; a new weight
    /// of exactly `0.0` removes the edge.
    Reweight { row: usize, col: usize, w: f32 },
}

impl EdgeOp {
    /// The `(row, col)` this op targets.
    pub fn target(&self) -> (usize, usize) {
        match *self {
            EdgeOp::Add { row, col, .. }
            | EdgeOp::Remove { row, col }
            | EdgeOp::Reweight { row, col, .. } => (row, col),
        }
    }

    /// The weight this op writes, if any.
    pub fn weight(&self) -> Option<f32> {
        match *self {
            EdgeOp::Add { w, .. } | EdgeOp::Reweight { w, .. } => Some(w),
            EdgeOp::Remove { .. } => None,
        }
    }

    fn verb(&self) -> &'static str {
        match self {
            EdgeOp::Add { .. } => "add",
            EdgeOp::Remove { .. } => "remove",
            EdgeOp::Reweight { .. } => "reweight",
        }
    }

    /// The same op with row and column swapped — how a pins-frame op maps
    /// onto the `pinned` matrix and vice versa.
    fn mirrored(&self) -> EdgeOp {
        match *self {
            EdgeOp::Add { row, col, w } => EdgeOp::Add { row: col, col: row, w },
            EdgeOp::Remove { row, col } => EdgeOp::Remove { row: col, col: row },
            EdgeOp::Reweight { row, col, w } => EdgeOp::Reweight { row: col, col: row, w },
        }
    }
}

/// One engineering change order against a [`HeteroGraph`]: sparse edge
/// edits plus node-feature/label row updates. Node *counts* never change
/// under a delta — an ECO that grows the netlist is a new design.
///
/// Build with the chainable `add_edge`/`remove_edge`/`reweight_edge`/
/// `set_*` methods, apply with [`apply`]. `Pinned`-frame edge ops are
/// stored mirrored into pins coordinates, so a patch touching either side
/// of the relation always patches both matrices consistently.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DeltaPatch {
    /// `near` ops, (cell, cell).
    near: Vec<EdgeOp>,
    /// `pins`-frame ops, (net, cell) — covers `pinned` by mirroring.
    pins: Vec<EdgeOp>,
    /// Full-row replacements of cell features: `(cell, new_row)`.
    x_cell: Vec<(usize, Vec<f32>)>,
    /// Full-row replacements of net features: `(net, new_row)`.
    x_net: Vec<(usize, Vec<f32>)>,
    /// Label updates: `(cell, new_label)`.
    y_cell: Vec<(usize, f32)>,
}

impl DeltaPatch {
    pub fn new() -> DeltaPatch {
        DeltaPatch::default()
    }

    /// Append one edge op in `e`'s own coordinate frame.
    pub fn edge(mut self, e: EdgeType, op: EdgeOp) -> DeltaPatch {
        match e {
            EdgeType::Near => self.near.push(op),
            EdgeType::Pins => self.pins.push(op),
            EdgeType::Pinned => self.pins.push(op.mirrored()),
        }
        self
    }

    pub fn add_edge(self, e: EdgeType, row: usize, col: usize, w: f32) -> DeltaPatch {
        self.edge(e, EdgeOp::Add { row, col, w })
    }

    pub fn remove_edge(self, e: EdgeType, row: usize, col: usize) -> DeltaPatch {
        self.edge(e, EdgeOp::Remove { row, col })
    }

    pub fn reweight_edge(self, e: EdgeType, row: usize, col: usize, w: f32) -> DeltaPatch {
        self.edge(e, EdgeOp::Reweight { row, col, w })
    }

    /// Replace one cell's feature row.
    pub fn set_x_cell(mut self, cell: usize, row: Vec<f32>) -> DeltaPatch {
        self.x_cell.push((cell, row));
        self
    }

    /// Replace one net's feature row.
    pub fn set_x_net(mut self, net: usize, row: Vec<f32>) -> DeltaPatch {
        self.x_net.push((net, row));
        self
    }

    /// Replace one cell's congestion label.
    pub fn set_y_cell(mut self, cell: usize, y: f32) -> DeltaPatch {
        self.y_cell.push((cell, y));
        self
    }

    /// An identity patch — [`apply`] returns a bit-identical graph.
    pub fn is_empty(&self) -> bool {
        self.near.is_empty()
            && self.pins.is_empty()
            && self.x_cell.is_empty()
            && self.x_net.is_empty()
            && self.y_cell.is_empty()
    }

    /// Whether this patch edits an edge type's adjacency. A pins-frame op
    /// touches both `Pins` and `Pinned` (one relation, two matrices).
    pub fn touches(&self, e: EdgeType) -> bool {
        match e {
            EdgeType::Near => !self.near.is_empty(),
            EdgeType::Pins | EdgeType::Pinned => !self.pins.is_empty(),
        }
    }

    /// Total edge ops (pins-frame ops counted once).
    pub fn n_edge_ops(&self) -> usize {
        self.near.len() + self.pins.len()
    }

    /// The edge ops for one type, in that type's coordinate frame
    /// (`Pinned` returns the mirrored pins ops). Used by the partition
    /// router to re-express a parent ECO per subgraph.
    pub fn ops(&self, e: EdgeType) -> Vec<EdgeOp> {
        match e {
            EdgeType::Near => self.near.clone(),
            EdgeType::Pins => self.pins.clone(),
            EdgeType::Pinned => self.pins.iter().map(|op| op.mirrored()).collect(),
        }
    }

    /// Feature-row updates for cells: `(cell, new_row)`.
    pub fn x_cell_updates(&self) -> &[(usize, Vec<f32>)] {
        &self.x_cell
    }

    /// Feature-row updates for nets: `(net, new_row)`.
    pub fn x_net_updates(&self) -> &[(usize, Vec<f32>)] {
        &self.x_net
    }

    /// Label updates: `(cell, new_label)`.
    pub fn y_cell_updates(&self) -> &[(usize, f32)] {
        &self.y_cell
    }

    /// Apply this patch to a graph (see [`apply`]).
    pub fn apply(&self, g: &HeteroGraph) -> Result<HeteroGraph, String> {
        apply(g, self)
    }

    /// One-line summary for logs.
    pub fn describe(&self) -> String {
        format!(
            "delta: {} near op(s), {} pin op(s), {} feature/label update(s)",
            self.near.len(),
            self.pins.len(),
            self.x_cell.len() + self.x_net.len() + self.y_cell.len()
        )
    }
}

/// Apply an ECO to a graph, returning the patched graph.
///
/// The result is bit-identical — same CSR arrays, same
/// `content_hash`/`adjacency_hash` — to rebuilding each adjacency from
/// its patched triplet list with [`Csr::from_triplets`] (asserted by
/// proptests in `tests/integration_delta.rs`). Node counts, graph id and
/// untouched features carry over unchanged. Errors (leaving `g` untouched)
/// on: out-of-bounds targets, `Add` of an existing edge, `Remove`/
/// `Reweight` of an absent edge, duplicate ops on one edge, non-finite
/// weights, or feature rows of the wrong width.
pub fn apply(g: &HeteroGraph, patch: &DeltaPatch) -> Result<HeteroGraph, String> {
    let near = apply_csr(&g.near, &patch.near, "near")?;
    let pins = apply_csr(&g.pins, &patch.pins, "pins")?;
    let mirrored: Vec<EdgeOp> = patch.pins.iter().map(|op| op.mirrored()).collect();
    let pinned = apply_csr(&g.pinned, &mirrored, "pinned")?;

    let mut x_cell = g.x_cell.clone();
    for (cell, row) in &patch.x_cell {
        if *cell >= g.n_cells {
            return Err(format!("x_cell update: cell {cell} out of bounds ({})", g.n_cells));
        }
        if row.len() != x_cell.cols {
            return Err(format!(
                "x_cell update for cell {cell}: width {} vs feature dim {}",
                row.len(),
                x_cell.cols
            ));
        }
        x_cell.row_mut(*cell).copy_from_slice(row);
    }
    let mut x_net = g.x_net.clone();
    for (net, row) in &patch.x_net {
        if *net >= g.n_nets {
            return Err(format!("x_net update: net {net} out of bounds ({})", g.n_nets));
        }
        if row.len() != x_net.cols {
            return Err(format!(
                "x_net update for net {net}: width {} vs feature dim {}",
                row.len(),
                x_net.cols
            ));
        }
        x_net.row_mut(*net).copy_from_slice(row);
    }
    let mut y_cell = g.y_cell.clone();
    for &(cell, y) in &patch.y_cell {
        if cell >= g.n_cells {
            return Err(format!("y_cell update: cell {cell} out of bounds ({})", g.n_cells));
        }
        y_cell.row_mut(cell)[0] = y;
    }

    let out = HeteroGraph {
        id: g.id,
        n_cells: g.n_cells,
        n_nets: g.n_nets,
        near,
        pins,
        pinned,
        x_cell,
        x_net,
        y_cell,
    };
    out.validate()?;
    Ok(out)
}

/// Patch one canonical CSR: untouched rows are copied wholesale; edited
/// rows merge the old sorted entries with the (sorted, deduplicated) ops
/// and re-canonicalize through the shared [`push_canonical_row`] — which
/// is what makes the result bit-identical to a from-scratch
/// [`Csr::from_triplets`] over the patched triplets.
fn apply_csr(m: &Csr, ops: &[EdgeOp], what: &str) -> Result<Csr, String> {
    if ops.is_empty() {
        return Ok(m.clone());
    }
    let mut by_row: std::collections::BTreeMap<usize, Vec<(u32, EdgeOp)>> =
        std::collections::BTreeMap::new();
    for &op in ops {
        let (r, c) = op.target();
        if r >= m.rows || c >= m.cols {
            return Err(format!(
                "{what}: op targets ({r},{c}) outside {}×{}",
                m.rows, m.cols
            ));
        }
        if let Some(w) = op.weight() {
            if !w.is_finite() {
                return Err(format!("{what}: non-finite weight {w} at ({r},{c})"));
            }
        }
        by_row.entry(r).or_default().push((c as u32, op));
    }
    for (r, edits) in by_row.iter_mut() {
        edits.sort_by_key(|&(c, _)| c);
        if let Some(w) = edits.windows(2).find(|w| w[0].0 == w[1].0) {
            return Err(format!(
                "{what}: duplicate ops target edge ({r},{}) — one op per edge per patch",
                w[0].0
            ));
        }
    }

    let mut indptr = vec![0usize; m.rows + 1];
    let mut indices = Vec::with_capacity(m.nnz() + ops.len());
    let mut values = Vec::with_capacity(m.nnz() + ops.len());
    let mut merged: Vec<(u32, f32)> = Vec::new();
    for r in 0..m.rows {
        match by_row.get(&r) {
            None => {
                let range = m.row_range(r);
                indices.extend_from_slice(&m.indices[range.clone()]);
                values.extend_from_slice(&m.values[range]);
            }
            Some(edits) => {
                merged.clear();
                let range = m.row_range(r);
                let old_cols = &m.indices[range.clone()];
                let old_vals = &m.values[range];
                let (mut i, mut j) = (0usize, 0usize);
                while i < old_cols.len() || j < edits.len() {
                    if j >= edits.len() || (i < old_cols.len() && old_cols[i] < edits[j].0) {
                        merged.push((old_cols[i], old_vals[i]));
                        i += 1;
                    } else if i >= old_cols.len() || edits[j].0 < old_cols[i] {
                        // Op on an edge the matrix does not hold.
                        let (c, op) = edits[j];
                        match op {
                            EdgeOp::Add { w, .. } => merged.push((c, w)),
                            EdgeOp::Remove { .. } | EdgeOp::Reweight { .. } => {
                                return Err(format!(
                                    "{what}: no edge at ({r},{c}) to {}",
                                    op.verb()
                                ));
                            }
                        }
                        j += 1;
                    } else {
                        // Op on an existing edge.
                        let (c, op) = edits[j];
                        match op {
                            EdgeOp::Add { .. } => {
                                return Err(format!(
                                    "{what}: edge ({r},{c}) already exists — use Reweight"
                                ));
                            }
                            EdgeOp::Remove { .. } => {}
                            EdgeOp::Reweight { w, .. } => merged.push((c, w)),
                        }
                        i += 1;
                        j += 1;
                    }
                }
                push_canonical_row(&merged, &mut indices, &mut values);
            }
        }
        indptr[r + 1] = indices.len();
    }
    Ok(Csr { rows: m.rows, cols: m.cols, indptr, indices, values })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Matrix;

    fn toy() -> HeteroGraph {
        let near = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)],
        );
        let pins =
            Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 1, 1.0), (1, 2, 1.0)]);
        let pinned = pins.transpose();
        HeteroGraph {
            id: 7,
            n_cells: 3,
            n_nets: 2,
            near,
            pins,
            pinned,
            x_cell: Matrix::from_fn(3, 4, |r, c| (r * 4 + c) as f32),
            x_net: Matrix::ones(2, 4),
            y_cell: Matrix::zeros(3, 1),
        }
    }

    #[test]
    fn identity_patch_is_bit_identical() {
        let g = toy();
        let p = DeltaPatch::new();
        assert!(p.is_empty());
        let out = apply(&g, &p).unwrap();
        assert_eq!(out.adjacency_hash(), g.adjacency_hash());
        assert_eq!(out.near, g.near);
        assert_eq!(out.x_cell.data, g.x_cell.data);
        assert_eq!(out.id, g.id);
    }

    #[test]
    fn add_remove_reweight_match_from_scratch() {
        let g = toy();
        let p = DeltaPatch::new()
            .add_edge(EdgeType::Near, 0, 2, 0.5)
            .remove_edge(EdgeType::Near, 1, 0)
            .reweight_edge(EdgeType::Near, 2, 1, 3.0);
        let out = apply(&g, &p).unwrap();
        let want = Csr::from_triplets(
            3,
            3,
            &[(0, 1, 1.0), (0, 2, 0.5), (1, 2, 1.0), (2, 1, 3.0)],
        );
        assert_eq!(out.near, want);
        assert_eq!(out.near.content_hash(), want.content_hash());
        out.validate().unwrap();
    }

    #[test]
    fn pinned_frame_ops_mirror_into_both_matrices() {
        let g = toy();
        // Same logical edit expressed in either frame must agree: net 1
        // gains a pin on cell 0.
        let via_pins = apply(&g, &DeltaPatch::new().add_edge(EdgeType::Pins, 1, 0, 1.0)).unwrap();
        let via_pinned =
            apply(&g, &DeltaPatch::new().add_edge(EdgeType::Pinned, 0, 1, 1.0)).unwrap();
        assert_eq!(via_pins.adjacency_hash(), via_pinned.adjacency_hash());
        assert_eq!(via_pins.pins, via_pinned.pins);
        assert_eq!(via_pins.pinned, via_pinned.pinned);
        assert!(via_pins.pinned.is_transpose_of(&via_pins.pins));
        // And it matches the from-scratch build of the patched relation.
        let want_pins = Csr::from_triplets(
            2,
            3,
            &[(0, 0, 1.0), (0, 1, 1.0), (1, 0, 1.0), (1, 1, 1.0), (1, 2, 1.0)],
        );
        assert_eq!(via_pins.pins, want_pins);
        assert_eq!(via_pins.pinned, want_pins.transpose());
    }

    #[test]
    fn add_then_remove_round_trips_to_original_hash() {
        // The canonical-form bugfix in action: an ECO that adds an edge
        // and a later ECO that removes it restore the original hash.
        let g = toy();
        let h0 = g.adjacency_hash();
        let added = apply(&g, &DeltaPatch::new().add_edge(EdgeType::Near, 0, 2, 0.25)).unwrap();
        assert_ne!(added.adjacency_hash(), h0);
        let back = apply(&added, &DeltaPatch::new().remove_edge(EdgeType::Near, 0, 2)).unwrap();
        assert_eq!(back.adjacency_hash(), h0);
        assert_eq!(back.near, g.near);
        // Reweight-to-zero is the same removal.
        let zeroed =
            apply(&added, &DeltaPatch::new().reweight_edge(EdgeType::Near, 0, 2, 0.0)).unwrap();
        assert_eq!(zeroed.adjacency_hash(), h0);
    }

    #[test]
    fn feature_and_label_updates() {
        let g = toy();
        let p = DeltaPatch::new()
            .set_x_cell(1, vec![9.0, 8.0, 7.0, 6.0])
            .set_x_net(0, vec![2.0; 4])
            .set_y_cell(2, 0.75);
        assert!(!p.is_empty());
        assert!(!p.touches(EdgeType::Near));
        let out = apply(&g, &p).unwrap();
        // Features never move the adjacency hash.
        assert_eq!(out.adjacency_hash(), g.adjacency_hash());
        assert_eq!(out.x_cell.row(1), &[9.0, 8.0, 7.0, 6.0]);
        assert_eq!(out.x_cell.row(0), g.x_cell.row(0));
        assert_eq!(out.x_net.row(0), &[2.0; 4]);
        assert_eq!(out.y_cell.at(2, 0), 0.75);
    }

    #[test]
    fn invalid_ops_error_and_leave_no_trace() {
        let g = toy();
        for (p, needle) in [
            (DeltaPatch::new().add_edge(EdgeType::Near, 0, 1, 2.0), "already exists"),
            (DeltaPatch::new().remove_edge(EdgeType::Near, 0, 0), "no edge"),
            (DeltaPatch::new().reweight_edge(EdgeType::Pins, 0, 2, 1.0), "no edge"),
            (DeltaPatch::new().add_edge(EdgeType::Near, 9, 0, 1.0), "outside"),
            (DeltaPatch::new().add_edge(EdgeType::Near, 0, 2, f32::NAN), "non-finite"),
            (
                DeltaPatch::new()
                    .remove_edge(EdgeType::Near, 0, 1)
                    .reweight_edge(EdgeType::Near, 0, 1, 2.0),
                "duplicate ops",
            ),
            (
                // Same logical pin edited through both frames = duplicate.
                DeltaPatch::new()
                    .remove_edge(EdgeType::Pins, 0, 0)
                    .reweight_edge(EdgeType::Pinned, 0, 0, 2.0),
                "duplicate ops",
            ),
            (DeltaPatch::new().set_x_cell(0, vec![1.0]), "width"),
            (DeltaPatch::new().set_y_cell(5, 1.0), "out of bounds"),
        ] {
            let err = apply(&g, &p).unwrap_err();
            assert!(err.contains(needle), "{err:?} missing {needle:?}");
        }
    }

    #[test]
    fn ops_accessor_round_trips_frames() {
        let p = DeltaPatch::new().add_edge(EdgeType::Pinned, 2, 1, 0.5);
        assert_eq!(p.ops(EdgeType::Pins), vec![EdgeOp::Add { row: 1, col: 2, w: 0.5 }]);
        assert_eq!(p.ops(EdgeType::Pinned), vec![EdgeOp::Add { row: 2, col: 1, w: 0.5 }]);
        assert_eq!(p.n_edge_ops(), 1);
        assert!(p.touches(EdgeType::Pins) && p.touches(EdgeType::Pinned));
    }
}
