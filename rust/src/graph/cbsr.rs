//! CBSR — Compressed Balanced Sparse Row (paper §3.1).
//!
//! The output format of D-ReLU: every embedding row keeps exactly `k`
//! surviving entries, stored as an `n × k` value matrix plus an `n × k`
//! column-index matrix. The *balance* (fixed k per row) is what lets the
//! DR-SpMM kernels assign regular per-warp workloads, unlike the irregular
//! sparsity ReLU leaves behind.

use crate::tensor::Matrix;

/// Compressed Balanced Sparse Row embedding: `n` rows, original width `dim`,
/// exactly `k` kept entries per row.
#[derive(Clone, Debug, PartialEq)]
pub struct Cbsr {
    pub n: usize,
    /// Original (decompressed) embedding width D.
    pub dim: usize,
    /// Kept entries per row (k ≤ dim).
    pub k: usize,
    /// Row-major `n × k` surviving values.
    pub values: Vec<f32>,
    /// Row-major `n × k` column positions of the surviving values, each
    /// strictly increasing within a row.
    pub indices: Vec<u32>,
}

impl Cbsr {
    pub fn zeros(n: usize, dim: usize, k: usize) -> Cbsr {
        assert!(k <= dim && k > 0, "need 0 < k ≤ dim (k={k}, dim={dim})");
        Cbsr {
            n,
            dim,
            k,
            values: vec![0.0; n * k],
            // Default indices 0..k keep rows valid (strictly increasing).
            indices: (0..n).flat_map(|_| 0..k as u32).collect(),
        }
    }

    #[inline]
    pub fn row_values(&self, r: usize) -> &[f32] {
        &self.values[r * self.k..(r + 1) * self.k]
    }

    #[inline]
    pub fn row_indices(&self, r: usize) -> &[u32] {
        &self.indices[r * self.k..(r + 1) * self.k]
    }

    /// Decompress to a dense `n × dim` matrix (reference/tests).
    pub fn to_dense(&self) -> Matrix {
        let mut out = Matrix::zeros(self.n, self.dim);
        for r in 0..self.n {
            let row = out.row_mut(r);
            for (v, &c) in self.row_values(r).iter().zip(self.row_indices(r)) {
                row[c as usize] = *v;
            }
        }
        out
    }

    /// Validate structural invariants: index bounds and strict ordering.
    pub fn validate(&self) -> Result<(), String> {
        if self.values.len() != self.n * self.k || self.indices.len() != self.n * self.k {
            return Err("storage size mismatch".into());
        }
        for r in 0..self.n {
            let idx = self.row_indices(r);
            for (i, &c) in idx.iter().enumerate() {
                if c as usize >= self.dim {
                    return Err(format!("row {r}: index {c} ≥ dim {}", self.dim));
                }
                if i > 0 && idx[i - 1] >= c {
                    return Err(format!("row {r}: indices not strictly increasing"));
                }
            }
        }
        Ok(())
    }

    /// Number of stored non-zeros (n·k by construction).
    pub fn stored(&self) -> usize {
        self.n * self.k
    }

    /// Compression ratio vs dense (k/D) — the kernel's FLOP/byte saving.
    pub fn density(&self) -> f64 {
        self.k as f64 / self.dim as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_is_valid() {
        let c = Cbsr::zeros(3, 8, 4);
        c.validate().unwrap();
        assert_eq!(c.stored(), 12);
        assert!((c.density() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn dense_round_trip_places_values() {
        let mut c = Cbsr::zeros(2, 6, 2);
        c.values.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        c.indices.copy_from_slice(&[1, 5, 0, 3]);
        c.validate().unwrap();
        let d = c.to_dense();
        assert_eq!(d.at(0, 1), 1.0);
        assert_eq!(d.at(0, 5), 2.0);
        assert_eq!(d.at(1, 0), 3.0);
        assert_eq!(d.at(1, 3), 4.0);
        assert_eq!(d.data.iter().filter(|&&x| x != 0.0).count(), 4);
    }

    #[test]
    fn validate_catches_bad_indices() {
        let mut c = Cbsr::zeros(1, 4, 2);
        c.indices.copy_from_slice(&[3, 3]); // not strictly increasing
        assert!(c.validate().is_err());
        c.indices.copy_from_slice(&[1, 9]); // out of bounds
        assert!(c.validate().is_err());
    }

    #[test]
    #[should_panic(expected = "need 0 < k")]
    fn k_larger_than_dim_panics() {
        Cbsr::zeros(1, 4, 5);
    }
}
