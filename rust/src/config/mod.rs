//! Configuration system: TOML-subset files + CLI overrides.
//!
//! Precedence: built-in defaults < config file (`--config path`) < CLI
//! flags. Everything the launcher needs — dataset scale, model hyper-
//! parameters, kernel/engine selection, schedule mode, artifact paths.
//!
//! Kernel strings (`--kernel`, `kernel.kind`) are parsed by the engine
//! registry ([`KernelSpec::parse`]) — the single parse point — so config
//! accepts exactly the registry vocabulary, including `"auto"`. Fleet
//! strings (`--fleet`, `fleet`) go through [`FleetSpec::parse`] the same
//! way.

use crate::datagen::WindowSpec;
use crate::engine::{Engine, EngineBuilder, KernelSpec};
use crate::fleet::FleetSpec;
use crate::sched::ScheduleMode;
use crate::util::cli::Args;
use crate::util::configfile::ConfigFile;
use std::path::PathBuf;

/// Full application configuration.
#[derive(Clone, Debug)]
pub struct Config {
    // dataset
    pub seed: u64,
    pub scale: f64,
    pub n_designs: usize,
    // model
    pub hidden: usize,
    pub epochs: usize,
    pub lr: f32,
    pub weight_decay: f32,
    pub k_cell: usize,
    pub k_net: usize,
    // execution
    pub kernel: KernelSpec,
    pub parallel: bool,
    /// Batched multi-subgraph execution (`--fleet`, `fleet`).
    pub fleet: FleetSpec,
    /// Fleet-level epoch pipelining (`--epoch-pipeline on|off`,
    /// `epoch_pipeline`): overlap design N+1's prepare stage with design
    /// N's execute + optimizer step. Requires fleet mode; results are
    /// bit-identical to the serial epoch schedule.
    pub epoch_pipeline: bool,
    /// Window-sampled training (`--window <count>x<cells>`, `window`):
    /// per epoch each design contributes `count` seeded windows of
    /// `cells` contiguous cells, trained as the fleet's subgraphs instead
    /// of the full graphs. Requires fleet mode. `Off` = full-graph
    /// training (the default; golden traces are pinned to it).
    pub window: WindowSpec,
    /// Activation checkpointing (`--checkpoint on|off`, `checkpoint`):
    /// the forward pass stores only per-layer checkpoints and the
    /// backward pass recomputes each layer's activations on demand —
    /// bit-identical gradients, roughly one extra forward pass of time,
    /// peak activation memory of a single layer.
    pub checkpoint: bool,
    /// Root thread budget (`--threads`, `threads`): the single cap that
    /// fleet workers × §3.4 edge lanes × kernel `parallel_for` subdivide
    /// ([`crate::util::pool::Budget`]). `None` = `DRCG_THREADS` env var or
    /// the machine's available parallelism. Applied once at startup via
    /// [`crate::util::pool::set_root_threads`] (first use wins).
    pub threads: Option<usize>,
    pub dim: usize,
    // paths
    pub artifacts_dir: PathBuf,
    pub out_dir: PathBuf,
    /// Persistent plan store directory (`--plan-store`,
    /// `paths.plan_store`): kernel plans and K profiles are loaded from /
    /// stored to it keyed by adjacency content-hash + engine signature,
    /// warm-starting Alg. 1 stage 1 across process restarts. `None` =
    /// in-memory cache only.
    pub plan_store: Option<PathBuf>,
    // serve
    /// Jobs file for serve mode (`--serve <path>`): one
    /// `design=… key=value…` job per line. `Some` selects the serve
    /// subcommand's workload.
    pub serve_jobs: Option<PathBuf>,
    /// Serve worker threads (`--serve-workers`, `serve.workers`).
    pub serve_workers: usize,
    /// Serve queue capacity (`--queue-cap`, `serve.queue_cap`);
    /// producers block when the queue is full.
    pub queue_cap: usize,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            seed: 42,
            scale: 0.1,
            n_designs: 12,
            hidden: 64,
            epochs: 50,
            lr: 2e-4,
            weight_decay: 1e-5,
            k_cell: 8,
            k_net: 8,
            kernel: KernelSpec::Dr,
            parallel: true,
            fleet: FleetSpec::Off,
            epoch_pipeline: false,
            window: WindowSpec::Off,
            checkpoint: false,
            threads: None,
            dim: 64,
            artifacts_dir: PathBuf::from("artifacts"),
            out_dir: PathBuf::from("out"),
            plan_store: None,
            serve_jobs: None,
            serve_workers: 2,
            queue_cap: 16,
        }
    }
}

impl Config {
    /// Load from an optional file then apply CLI overrides.
    pub fn resolve(args: &Args) -> Result<Config, String> {
        let mut cfg = Config::default();
        if let Some(path) = args.get("config") {
            let file = ConfigFile::load(std::path::Path::new(path))?;
            cfg.apply_file(&file)?;
        }
        cfg.apply_args(args)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn apply_file(&mut self, f: &ConfigFile) -> Result<(), String> {
        macro_rules! take {
            ($field:expr, $get:ident, $key:expr) => {
                if let Some(v) = f.$get($key) {
                    $field = v?;
                }
            };
        }
        if let Some(v) = f.get_usize("seed") {
            self.seed = v? as u64;
        }
        if let Some(v) = f.get("data.scale") {
            self.scale = v.parse().map_err(|_| "data.scale: bad float".to_string())?;
        }
        take!(self.n_designs, get_usize, "data.designs");
        take!(self.hidden, get_usize, "model.hidden");
        take!(self.epochs, get_usize, "train.epochs");
        take!(self.lr, get_f32, "train.lr");
        take!(self.weight_decay, get_f32, "train.weight_decay");
        take!(self.k_cell, get_usize, "kernel.k_cell");
        take!(self.k_net, get_usize, "kernel.k_net");
        take!(self.dim, get_usize, "kernel.dim");
        if let Some(v) = f.get("kernel.kind") {
            self.kernel = KernelSpec::parse(v).map_err(|e| format!("kernel.kind: {e}"))?;
        }
        if let Some(v) = f.get_bool("sched.parallel") {
            self.parallel = v?;
        }
        if let Some(v) = f.get("fleet") {
            self.fleet = FleetSpec::parse(v).map_err(|e| format!("fleet: {e}"))?;
        }
        if let Some(v) = f.get("epoch_pipeline") {
            self.epoch_pipeline =
                parse_on_off(v).map_err(|e| format!("epoch_pipeline: {e}"))?;
        }
        if let Some(v) = f.get("window") {
            self.window = WindowSpec::parse(v).map_err(|e| format!("window: {e}"))?;
        }
        if let Some(v) = f.get("checkpoint") {
            self.checkpoint = parse_on_off(v).map_err(|e| format!("checkpoint: {e}"))?;
        }
        if let Some(v) = f.get_usize("threads") {
            self.threads = Some(v?);
        }
        if let Some(v) = f.get("paths.artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = f.get("paths.out") {
            self.out_dir = PathBuf::from(v);
        }
        if let Some(v) = f.get("paths.plan_store") {
            self.plan_store = Some(PathBuf::from(v));
        }
        if let Some(v) = f.get("serve.jobs") {
            self.serve_jobs = Some(PathBuf::from(v));
        }
        take!(self.serve_workers, get_usize, "serve.workers");
        take!(self.queue_cap, get_usize, "serve.queue_cap");
        Ok(())
    }

    pub fn apply_args(&mut self, a: &Args) -> Result<(), String> {
        self.seed = a.get_usize("seed", self.seed as usize)? as u64;
        self.scale = a.get_f64("scale", self.scale)?;
        self.n_designs = a.get_usize("designs", self.n_designs)?;
        self.hidden = a.get_usize("hidden", self.hidden)?;
        self.epochs = a.get_usize("epochs", self.epochs)?;
        self.lr = a.get_f32("lr", self.lr)?;
        self.weight_decay = a.get_f32("weight-decay", self.weight_decay)?;
        self.k_cell = a.get_usize("k-cell", self.k_cell)?;
        self.k_net = a.get_usize("k-net", self.k_net)?;
        self.dim = a.get_usize("dim", self.dim)?;
        if let Some(v) = a.get("kernel") {
            self.kernel = KernelSpec::parse(v).map_err(|e| format!("--kernel: {e}"))?;
        }
        if a.flag("sequential") {
            self.parallel = false;
        }
        if a.flag("parallel") {
            self.parallel = true;
        }
        if let Some(v) = a.get("fleet") {
            self.fleet = FleetSpec::parse(v).map_err(|e| format!("--fleet: {e}"))?;
        }
        if let Some(v) = a.get("epoch-pipeline") {
            self.epoch_pipeline =
                parse_on_off(v).map_err(|e| format!("--epoch-pipeline: {e}"))?;
        }
        if let Some(v) = a.get("window") {
            self.window = WindowSpec::parse(v).map_err(|e| format!("--window: {e}"))?;
        }
        if let Some(v) = a.get("checkpoint") {
            self.checkpoint = parse_on_off(v).map_err(|e| format!("--checkpoint: {e}"))?;
        }
        if let Some(v) = a.get("threads") {
            let t: usize =
                v.parse().map_err(|_| format!("--threads: expected integer, got '{v}'"))?;
            self.threads = Some(t);
        }
        if let Some(v) = a.get("artifacts") {
            self.artifacts_dir = PathBuf::from(v);
        }
        if let Some(v) = a.get("out") {
            self.out_dir = PathBuf::from(v);
        }
        if let Some(v) = a.get("plan-store") {
            self.plan_store = Some(PathBuf::from(v));
        }
        if let Some(v) = a.get("serve") {
            self.serve_jobs = Some(PathBuf::from(v));
        }
        self.serve_workers = a.get_usize("serve-workers", self.serve_workers)?;
        self.queue_cap = a.get_usize("queue-cap", self.queue_cap)?;
        Ok(())
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.scale <= 0.0 || self.scale > 1.0 {
            return Err(format!("scale must be in (0, 1], got {}", self.scale));
        }
        if self.hidden == 0 || self.epochs == 0 {
            return Err("hidden and epochs must be positive".into());
        }
        for (name, k) in [("k_cell", self.k_cell), ("k_net", self.k_net)] {
            if k == 0 || k > self.hidden {
                return Err(format!("{name} must be in [1, hidden], got {k}"));
            }
        }
        if self.threads == Some(0) {
            return Err("threads must be ≥ 1 (omit it for auto)".into());
        }
        if self.serve_workers == 0 {
            return Err("serve-workers must be ≥ 1".into());
        }
        if self.queue_cap == 0 {
            return Err("queue-cap must be ≥ 1".into());
        }
        if self.epoch_pipeline && !self.fleet.is_on() {
            return Err(
                "epoch-pipeline requires fleet mode (--fleet <workers>[x<parts>]); \
                 the pipeline overlaps one design's prepare with another's execute"
                    .into(),
            );
        }
        if self.window.is_on() && !self.fleet.is_on() {
            return Err(
                "window requires fleet mode (--fleet <workers>); sampled windows \
                 are trained as the fleet's subgraphs"
                    .into(),
            );
        }
        Ok(())
    }

    /// The engine builder this config selects (kernel spec for every edge
    /// type, D-ReLU K values, §3.4 schedule mode).
    pub fn engine_builder(&self) -> EngineBuilder {
        Engine::builder()
            .kernel_spec(self.kernel)
            .k_cell(self.k_cell)
            .k_net(self.k_net)
            .parallel(self.parallel)
    }

    pub fn schedule(&self) -> ScheduleMode {
        if self.parallel {
            ScheduleMode::Parallel
        } else {
            ScheduleMode::Sequential
        }
    }
}

/// Parse an `on|off` toggle (the `--epoch-pipeline` grammar; `true`/
/// `false` and `1`/`0` accepted as aliases so the config-file boolean
/// style works on the CLI too). The single parse point for the flag.
fn parse_on_off(s: &str) -> Result<bool, String> {
    match s.trim().to_ascii_lowercase().as_str() {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => Err(format!("expected on|off, got '{other}'")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{EdgeType, NodeType};

    fn raw(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_validate() {
        Config::default().validate().unwrap();
    }

    #[test]
    fn cli_overrides() {
        let args = Args::default()
            .parse(&raw(&["--epochs", "5", "--kernel", "csr", "--sequential", "--k-cell=16"]))
            .unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.epochs, 5);
        assert_eq!(cfg.kernel, KernelSpec::Csr);
        assert!(!cfg.parallel);
        assert_eq!(cfg.k_cell, 16);
    }

    #[test]
    fn file_then_cli_precedence() {
        let mut cfg = Config::default();
        let f = ConfigFile::parse("[train]\nepochs = 7\nlr = 0.01").unwrap();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.epochs, 7);
        let args = Args::default().parse(&raw(&["--epochs", "9"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.epochs, 9);
        assert_eq!(cfg.lr, 0.01);
    }

    #[test]
    fn engine_builder_mapping() {
        let mut cfg = Config::default();
        cfg.kernel = KernelSpec::Dr;
        cfg.k_cell = 4;
        cfg.k_net = 2;
        cfg.parallel = false;
        let b = cfg.engine_builder();
        assert_eq!(b.spec_for(EdgeType::Near), KernelSpec::Dr);
        assert_eq!(b.k_for(NodeType::Cell), 4);
        assert_eq!(b.k_for(NodeType::Net), 2);
        assert!(!b.is_parallel());
        cfg.kernel = KernelSpec::Gnna;
        assert_eq!(cfg.engine_builder().describe(), "GNNA");
    }

    #[test]
    fn fleet_parsed_through_single_parse_point() {
        // CLI surface.
        let args = Args::default().parse(&raw(&["--fleet", "4x2"])).unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.fleet, FleetSpec::On { workers: 4, parts: Some(2) });
        // File surface, overridden by CLI (precedence).
        let mut cfg = Config::default();
        let f = ConfigFile::parse("fleet = \"8\"").unwrap();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.fleet, FleetSpec::On { workers: 8, parts: None });
        let args = Args::default().parse(&raw(&["--fleet", "off"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.fleet, FleetSpec::Off);
        // Junk rejected with the grammar.
        let args = Args::default().parse(&raw(&["--fleet", "lots"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("<workers>"), "{err}");
    }

    #[test]
    fn epoch_pipeline_parsed_and_gated_on_fleet() {
        // Defaults off.
        assert!(!Config::default().epoch_pipeline);
        // CLI surface: requires fleet mode.
        let args = Args::default()
            .parse(&raw(&["--fleet", "4", "--epoch-pipeline", "on"]))
            .unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert!(cfg.epoch_pipeline);
        let args = Args::default()
            .parse(&raw(&["--fleet", "4", "--epoch-pipeline", "off"]))
            .unwrap();
        assert!(!Config::resolve(&args).unwrap().epoch_pipeline);
        // Without fleet mode the flag is rejected loudly.
        let args = Args::default().parse(&raw(&["--epoch-pipeline", "on"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("fleet"), "{err}");
        // Junk rejected with the grammar.
        let args = Args::default()
            .parse(&raw(&["--fleet", "2", "--epoch-pipeline", "maybe"]))
            .unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("on|off"), "{err}");
        // File surface (boolean-ish), overridden by CLI.
        let mut cfg = Config::default();
        let f = ConfigFile::parse("fleet = \"2\"\nepoch_pipeline = \"on\"").unwrap();
        cfg.apply_file(&f).unwrap();
        assert!(cfg.epoch_pipeline);
        let args = Args::default().parse(&raw(&["--epoch-pipeline", "off"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(!cfg.epoch_pipeline);
    }

    #[test]
    fn window_parsed_and_gated_on_fleet() {
        // Defaults off.
        assert_eq!(Config::default().window, WindowSpec::Off);
        // CLI surface: requires fleet mode.
        let args = Args::default().parse(&raw(&["--fleet", "4", "--window", "2x500"])).unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.window, WindowSpec::On { count: 2, cells: 500 });
        // Without fleet mode the flag is rejected loudly.
        let args = Args::default().parse(&raw(&["--window", "2x500"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("fleet"), "{err}");
        // Junk rejected with the grammar (a bare count is an error, not a
        // silently-defaulted window size).
        let args = Args::default().parse(&raw(&["--fleet", "2", "--window", "4"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("<count>x<cells>"), "{err}");
        // File surface, overridden by CLI.
        let mut cfg = Config::default();
        let f = ConfigFile::parse("fleet = \"2\"\nwindow = \"3x100\"").unwrap();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.window, WindowSpec::On { count: 3, cells: 100 });
        let args = Args::default().parse(&raw(&["--window", "off"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.window, WindowSpec::Off);
    }

    #[test]
    fn checkpoint_parsed_on_off() {
        // Defaults off; needs no fleet (it is a model-level toggle).
        assert!(!Config::default().checkpoint);
        let args = Args::default().parse(&raw(&["--checkpoint", "on"])).unwrap();
        assert!(Config::resolve(&args).unwrap().checkpoint);
        let args = Args::default().parse(&raw(&["--checkpoint", "off"])).unwrap();
        assert!(!Config::resolve(&args).unwrap().checkpoint);
        // Junk rejected with the grammar.
        let args = Args::default().parse(&raw(&["--checkpoint", "maybe"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("on|off"), "{err}");
        // File surface, overridden by CLI.
        let mut cfg = Config::default();
        let f = ConfigFile::parse("checkpoint = \"on\"").unwrap();
        cfg.apply_file(&f).unwrap();
        assert!(cfg.checkpoint);
        let args = Args::default().parse(&raw(&["--checkpoint", "off"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert!(!cfg.checkpoint);
    }

    #[test]
    fn threads_parsed_and_validated() {
        // Unset = auto (DRCG_THREADS / available parallelism).
        assert_eq!(Config::default().threads, None);
        // CLI surface.
        let args = Args::default().parse(&raw(&["--threads", "3"])).unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.threads, Some(3));
        // File surface, overridden by CLI (precedence).
        let mut cfg = Config::default();
        let f = ConfigFile::parse("threads = 8").unwrap();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.threads, Some(8));
        let args = Args::default().parse(&raw(&["--threads", "2"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.threads, Some(2));
        // Zero and junk rejected loudly.
        let args = Args::default().parse(&raw(&["--threads", "0"])).unwrap();
        assert!(Config::resolve(&args).unwrap_err().contains("threads"));
        let args = Args::default().parse(&raw(&["--threads", "many"])).unwrap();
        assert!(Config::resolve(&args).unwrap_err().contains("--threads"));
    }

    #[test]
    fn plan_store_and_serve_surfaces() {
        // Defaults: no store, no serve jobs, 2 workers, 16 capacity.
        let cfg = Config::default();
        assert_eq!(cfg.plan_store, None);
        assert_eq!(cfg.serve_jobs, None);
        assert_eq!(cfg.serve_workers, 2);
        assert_eq!(cfg.queue_cap, 16);
        // CLI surface.
        let args = Args::default()
            .parse(&raw(&[
                "--plan-store", "/tmp/plans", "--serve", "jobs.txt",
                "--serve-workers", "4", "--queue-cap", "8",
            ]))
            .unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.plan_store, Some(PathBuf::from("/tmp/plans")));
        assert_eq!(cfg.serve_jobs, Some(PathBuf::from("jobs.txt")));
        assert_eq!(cfg.serve_workers, 4);
        assert_eq!(cfg.queue_cap, 8);
        // File surface, overridden by CLI (precedence).
        let mut cfg = Config::default();
        let f = ConfigFile::parse(
            "[paths]\nplan_store = \"store\"\n[serve]\nworkers = 3\nqueue_cap = 5\njobs = \"j.txt\"",
        )
        .unwrap();
        cfg.apply_file(&f).unwrap();
        assert_eq!(cfg.plan_store, Some(PathBuf::from("store")));
        assert_eq!(cfg.serve_workers, 3);
        assert_eq!(cfg.queue_cap, 5);
        assert_eq!(cfg.serve_jobs, Some(PathBuf::from("j.txt")));
        let args = Args::default().parse(&raw(&["--serve-workers", "1"])).unwrap();
        cfg.apply_args(&args).unwrap();
        assert_eq!(cfg.serve_workers, 1);
        // Zeroes rejected loudly.
        let args = Args::default().parse(&raw(&["--serve-workers", "0"])).unwrap();
        assert!(Config::resolve(&args).unwrap_err().contains("serve-workers"));
        let args = Args::default().parse(&raw(&["--queue-cap", "0"])).unwrap();
        assert!(Config::resolve(&args).unwrap_err().contains("queue-cap"));
    }

    #[test]
    fn auto_kernel_accepted() {
        let args = Args::default().parse(&raw(&["--kernel", "auto"])).unwrap();
        let cfg = Config::resolve(&args).unwrap();
        assert_eq!(cfg.kernel, KernelSpec::Auto);
    }

    #[test]
    fn unknown_kernel_rejected_with_vocabulary() {
        let args = Args::default().parse(&raw(&["--kernel", "warp9"])).unwrap();
        let err = Config::resolve(&args).unwrap_err();
        assert!(err.contains("auto") && err.contains("csr"), "{err}");
    }

    #[test]
    fn invalid_configs_rejected() {
        let mut cfg = Config::default();
        cfg.scale = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = Config::default();
        cfg.k_cell = 1000;
        assert!(cfg.validate().is_err());
    }
}
