//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! Artifacts are HLO *text* files produced by `python/compile/aot.py`
//! (jax → stablehlo → XlaComputation → HLO text; the text parser reassigns
//! the 64-bit instruction ids that xla_extension 0.5.1's proto path
//! rejects). Each `<name>.hlo.txt` ships with a `<name>.meta` describing
//! input/output shapes so the coordinator can validate its feeds.
//!
//! Python never runs at request time: after `make artifacts`, the rust
//! binary is self-contained.

pub mod artifact;
pub mod client;
pub mod pad;

pub use artifact::{ArtifactMeta, ArtifactRegistry};
pub use client::{Executable, Runtime};
pub use pad::{pad_graph, pad_graph_strict, Bucket, PaddedGraph};
