//! Thin wrapper over the `xla` crate's PJRT CPU client.
//!
//! Two cargo features gate this module:
//! * `pjrt` — the runtime scaffolding; alone it still compiles the stub
//!   below (so CI's feature-matrix lane can build `--features pjrt`
//!   without native dependencies);
//! * `xla-backend` (implies `pjrt`) — the real client. The `xla` crate
//!   (xla-rs, pinned to `xla_extension` 0.5.1) is not on crates.io and
//!   needs the native `libxla_extension`; vendor it and add
//!   `xla = { path = "..." }` under `[dependencies]` before enabling.
//!
//! The stub's `Runtime::cpu()` returns an error that callers already
//! handle (the runtime tests and examples skip with a notice); the wrapped
//! API is identical either way.

use crate::tensor::Matrix;
use anyhow::Result;

#[cfg(feature = "xla-backend")]
mod imp {
    use super::*;
    use anyhow::Context;

    /// A PJRT client plus compilation entry points.
    pub struct Runtime {
        client: xla::PjRtClient,
    }

    impl Runtime {
        /// CPU PJRT client (the only backend in this environment).
        pub fn cpu() -> Result<Runtime> {
            let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
            Ok(Runtime { client })
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        pub fn device_count(&self) -> usize {
            self.client.device_count()
        }

        /// Load an HLO text artifact and compile it for this client.
        pub fn load_hlo_text(&self, path: &std::path::Path) -> Result<Executable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parsing HLO text {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?;
            Ok(Executable { exe, name: path.display().to_string() })
        }
    }

    /// A compiled executable with matrix-level convenience I/O.
    pub struct Executable {
        exe: xla::PjRtLoadedExecutable,
        pub name: String,
    }

    impl Executable {
        /// Execute with f32 tensor inputs given as (data, dims) pairs.
        /// Returns all outputs flattened to f32 vectors with their dims.
        /// The AOT path lowers with `return_tuple=True`, so the single
        /// result is a tuple literal that we decompose.
        pub fn run(&self, inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            let literals: Vec<xla::Literal> = inputs
                .iter()
                .map(|(data, dims)| {
                    let lit = xla::Literal::vec1(data);
                    lit.reshape(dims).context("reshaping input literal")
                })
                .collect::<Result<_>>()?;
            let result = self
                .exe
                .execute::<xla::Literal>(&literals)
                .with_context(|| format!("executing {}", self.name))?;
            let mut out = result[0][0]
                .to_literal_sync()
                .context("fetching result literal")?;
            let parts = out.decompose_tuple().context("decomposing result tuple")?;
            let parts = if parts.is_empty() { vec![out] } else { parts };
            parts
                .into_iter()
                .map(|lit| lit.to_vec::<f32>().context("reading f32 output"))
                .collect()
        }
    }
}

#[cfg(not(feature = "xla-backend"))]
mod imp {
    use super::*;

    /// Stub runtime compiled without the `xla-backend` feature.
    pub struct Runtime {
        _private: (),
    }

    impl Runtime {
        pub fn cpu() -> Result<Runtime> {
            // The two messages are feature-gated so `--features pjrt`
            // compiles a distinct configuration (CI's feature-matrix lane
            // exercises it) even though both are stubs without a backend.
            #[cfg(feature = "pjrt")]
            anyhow::bail!(
                "PJRT scaffolding enabled but no backend — vendor the xla-rs \
                 crate (add it under [dependencies] in rust/Cargo.toml, needs \
                 libxla_extension) and rebuild with `--features xla-backend`"
            );
            #[cfg(not(feature = "pjrt"))]
            anyhow::bail!(
                "PJRT support not compiled in — rebuild with `--features pjrt` \
                 for the scaffolding, plus vendored xla-rs and \
                 `--features xla-backend` for the real client"
            );
        }

        pub fn platform(&self) -> String {
            unreachable!("stub Runtime cannot be constructed")
        }

        pub fn device_count(&self) -> usize {
            unreachable!("stub Runtime cannot be constructed")
        }

        pub fn load_hlo_text(&self, _path: &std::path::Path) -> Result<Executable> {
            unreachable!("stub Runtime cannot be constructed")
        }
    }

    /// Stub executable (never constructed without the `pjrt` feature).
    pub struct Executable {
        pub name: String,
    }

    impl Executable {
        pub fn run(&self, _inputs: &[(&[f32], &[i64])]) -> Result<Vec<Vec<f32>>> {
            unreachable!("stub Executable cannot be constructed")
        }
    }
}

pub use imp::{Executable, Runtime};

impl Executable {
    /// Convenience: run with Matrix inputs; outputs returned as flat vecs.
    pub fn run_matrices(&self, inputs: &[&Matrix]) -> Result<Vec<Vec<f32>>> {
        let prepared: Vec<(&[f32], Vec<i64>)> = inputs
            .iter()
            .map(|m| (m.data.as_slice(), vec![m.rows as i64, m.cols as i64]))
            .collect();
        let refs: Vec<(&[f32], &[i64])> =
            prepared.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        self.run(&refs)
    }
}

#[cfg(test)]
mod tests {
    // PJRT-dependent tests live in rust/tests/integration_runtime.rs so the
    // unit suite stays independent of libxla_extension availability.
}
