//! Graph → static-bucket padding for the AOT artifacts.
//!
//! The HLO artifacts have fixed shapes (see python/compile/graph_spec.py
//! and each artifact's `.meta` bucket note). This module pads a real
//! heterograph into the bucket: ELL-encodes each adjacency (destination-
//! major forward + source-major transpose), zero-pads features/labels and
//! produces the cell mask used by the masked loss.

use crate::graph::{Csr, HeteroGraph};
use crate::sparse::EllLayout;
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Static bucket description (parsed from the artifact meta's bucket note).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub n_cell: usize,
    pub n_net: usize,
    pub w_near: usize,
    pub w_pins: usize,
    pub w_pinned: usize,
    pub hidden: usize,
    pub k_cell: usize,
    pub k_net: usize,
}

impl Bucket {
    /// Parse from a meta note like
    /// `bucket n_cell=256 n_net=128 w_near=64 w_pins=16 w_pinned=16 hidden=64 k_cell=8 k_net=8`.
    pub fn parse_note(note: &str) -> Result<Bucket> {
        let mut map = std::collections::BTreeMap::new();
        for tok in note.split_whitespace() {
            if let Some((k, v)) = tok.split_once('=') {
                map.insert(k.to_string(), v.parse::<usize>()?);
            }
        }
        let get = |k: &str| -> Result<usize> {
            map.get(k).copied().ok_or_else(|| anyhow::anyhow!("bucket note missing '{k}'"))
        };
        Ok(Bucket {
            n_cell: get("n_cell")?,
            n_net: get("n_net")?,
            w_near: get("w_near")?,
            w_pins: get("w_pins")?,
            w_pinned: get("w_pinned")?,
            hidden: get("hidden")?,
            k_cell: get("k_cell")?,
            k_net: get("k_net")?,
        })
    }
}

/// ELL encoding of one adjacency: idx/val as f32 matrices (`rows × width`),
/// plus how many entries were truncated by the width cap.
#[derive(Clone, Debug)]
pub struct Ell {
    pub idx: Matrix,
    pub val: Matrix,
    pub truncated: usize,
}

/// ELL-encode a CSR into `rows_cap × width`, truncating over-wide rows.
/// Index slots of padding entries point at row 0 with value 0 (harmless).
///
/// Slot assignment is [`EllLayout::build`] — the same plan-time layout the
/// `ell` registry kernel executes — so the padded artifact and the exact
/// kernel agree on every kept slot; the layout's lossless overflow list is
/// what a fixed-shape artifact cannot carry, so its size is reported as
/// `truncated` (callers decide whether that is a warning or an error).
pub fn to_ell(adj: &Csr, rows_cap: usize, width: usize) -> Result<Ell> {
    if adj.rows > rows_cap {
        bail!("adjacency rows {} exceed bucket capacity {}", adj.rows, rows_cap);
    }
    let layout = EllLayout::build(adj, width);
    let mut idx = Matrix::zeros(rows_cap, width);
    let mut val = Matrix::zeros(rows_cap, width);
    for r in 0..adj.rows {
        for s in 0..width {
            *idx.at_mut(r, s) = layout.idx[r * width + s] as f32;
            *val.at_mut(r, s) = layout.val[r * width + s];
        }
    }
    Ok(Ell { idx, val, truncated: layout.overflow_nnz() })
}

/// A heterograph padded into an artifact bucket, ready to feed PJRT.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub bucket: Bucket,
    /// The 12 graph tensors in `model.GRAPH_KEYS` order.
    pub graph_tensors: Vec<Matrix>,
    pub x_cell: Matrix,
    pub x_net: Matrix,
    pub y_cell: Matrix,
    pub cell_mask: Matrix,
    /// Total ELL truncation across all six encodings.
    pub truncated: usize,
    /// Real node counts before padding.
    pub real_cells: usize,
    pub real_nets: usize,
}

/// Pad a graph (with pre-normalised adjacencies) into the bucket.
///
/// Normalisation mirrors the training path: GCN-norm on `near`, row mean
/// on `pins`/`pinned`.
///
/// **Lossy**: rows wider than the bucket are truncated, which changes
/// numerics on the padded path. Every truncating adjacency is reported
/// with a loud [`crate::warn!`]; training paths should call
/// [`pad_graph_strict`] instead, which refuses to drop edges.
pub fn pad_graph(g: &HeteroGraph, bucket: Bucket) -> Result<PaddedGraph> {
    if g.n_cells > bucket.n_cell || g.n_nets > bucket.n_net {
        bail!(
            "graph ({} cells, {} nets) exceeds bucket ({}, {})",
            g.n_cells,
            g.n_nets,
            bucket.n_cell,
            bucket.n_net
        );
    }
    let mut near = g.near.clone();
    near.normalize_gcn();
    let mut pinned = g.pinned.clone();
    pinned.normalize_rows();
    let mut pins = g.pins.clone();
    pins.normalize_rows();

    // Forward (destination-major) and transposed (source-major) ELLs.
    let near_f = to_ell(&near, bucket.n_cell, bucket.w_near)?;
    let near_t = to_ell(&near.transpose(), bucket.n_cell, bucket.w_near)?;
    let pinned_f = to_ell(&pinned, bucket.n_cell, bucket.w_pinned)?;
    let pinned_t = to_ell(&pinned.transpose(), bucket.n_net, bucket.w_pins)?;
    let pins_f = to_ell(&pins, bucket.n_net, bucket.w_pins)?;
    let pins_t = to_ell(&pins.transpose(), bucket.n_cell, bucket.w_pinned)?;
    for (name, ell, width) in [
        ("near fwd", &near_f, bucket.w_near),
        ("near transpose", &near_t, bucket.w_near),
        ("pinned fwd", &pinned_f, bucket.w_pinned),
        ("pinned transpose", &pinned_t, bucket.w_pins),
        ("pins fwd", &pins_f, bucket.w_pins),
        ("pins transpose", &pins_t, bucket.w_pinned),
    ] {
        if ell.truncated > 0 {
            crate::warn!(
                "pad_graph: {name} ELL truncated {} edge(s) at width {width} — \
                 padded-path numerics will differ from the exact kernels \
                 (use pad_graph_strict to reject instead)",
                ell.truncated
            );
        }
    }
    let truncated = near_f.truncated
        + near_t.truncated
        + pinned_f.truncated
        + pinned_t.truncated
        + pins_f.truncated
        + pins_t.truncated;

    let pad_rows = |m: &Matrix, rows: usize| -> Matrix {
        let mut out = Matrix::zeros(rows, m.cols);
        for r in 0..m.rows {
            out.row_mut(r).copy_from_slice(m.row(r));
        }
        out
    };
    let mut cell_mask = Matrix::zeros(bucket.n_cell, 1);
    for r in 0..g.n_cells {
        cell_mask.data[r] = 1.0;
    }
    // GRAPH_KEYS order: near idx/val/idx_t/val_t, pinned ..., pins ...
    let graph_tensors = vec![
        near_f.idx, near_f.val, near_t.idx, near_t.val,
        pinned_f.idx, pinned_f.val, pinned_t.idx, pinned_t.val,
        pins_f.idx, pins_f.val, pins_t.idx, pins_t.val,
    ];
    Ok(PaddedGraph {
        bucket,
        graph_tensors,
        x_cell: pad_rows(&g.x_cell, bucket.n_cell),
        x_net: pad_rows(&g.x_net, bucket.n_net),
        y_cell: pad_rows(&g.y_cell, bucket.n_cell),
        cell_mask,
        truncated,
        real_cells: g.n_cells,
        real_nets: g.n_nets,
    })
}

/// Strict padding for training paths: identical to [`pad_graph`] except
/// that any width-cap truncation is an **error** — training must not drop
/// edges (silently changed numerics are how padded-path regressions hide).
pub fn pad_graph_strict(g: &HeteroGraph, bucket: Bucket) -> Result<PaddedGraph> {
    let p = pad_graph(g, bucket)?;
    if p.truncated > 0 {
        bail!(
            "bucket too narrow: padding truncated {} edge(s) \
             (widths near={} pins={} pinned={}); training must not drop edges — \
             use a wider bucket, or pad_graph for lossy inference padding",
            p.truncated,
            bucket.w_near,
            bucket.w_pins,
            bucket.w_pinned
        );
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::util::rng::Rng;

    fn bucket() -> Bucket {
        Bucket {
            n_cell: 256,
            n_net: 128,
            w_near: 64,
            w_pins: 16,
            w_pinned: 16,
            hidden: 64,
            k_cell: 8,
            k_net: 8,
        }
    }

    fn small() -> HeteroGraph {
        let mut rng = Rng::new(1);
        generate_graph(
            &GraphSpec {
                n_cells: 200,
                n_nets: 100,
                target_near: 4000,
                target_pins: 300,
                d_cell: 16,
                d_net: 16,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn parse_bucket_note() {
        let b = Bucket::parse_note(
            "bucket n_cell=256 n_net=128 w_near=64 w_pins=16 w_pinned=16 hidden=64 k_cell=8 k_net=8",
        )
        .unwrap();
        assert_eq!(b, bucket());
        assert!(Bucket::parse_note("bucket n_cell=1").is_err());
    }

    #[test]
    fn ell_round_trip_dense() {
        let adj = Csr::from_triplets(3, 5, &[(0, 1, 2.0), (0, 4, 3.0), (2, 0, 1.0)]);
        let ell = to_ell(&adj, 4, 3).unwrap();
        assert_eq!(ell.truncated, 0);
        assert_eq!(ell.idx.at(0, 0), 1.0);
        assert_eq!(ell.val.at(0, 1), 3.0);
        assert_eq!(ell.val.at(1, 0), 0.0); // empty row padded
        assert_eq!(ell.val.at(3, 0), 0.0); // padded row
    }

    #[test]
    fn ell_truncation_counted() {
        let t: Vec<_> = (0..10).map(|c| (0usize, c, 1.0f32)).collect();
        let adj = Csr::from_triplets(1, 10, &t);
        let ell = to_ell(&adj, 1, 4).unwrap();
        assert_eq!(ell.truncated, 6);
    }

    #[test]
    fn pad_graph_shapes_and_mask() {
        let g = small();
        let p = pad_graph(&g, bucket()).unwrap();
        assert_eq!(p.graph_tensors.len(), 12);
        assert_eq!((p.x_cell.rows, p.x_cell.cols), (256, 16));
        assert_eq!((p.x_net.rows, p.x_net.cols), (128, 16));
        assert_eq!(p.cell_mask.data.iter().filter(|&&v| v == 1.0).count(), 200);
        assert_eq!(p.real_cells, 200);
        // Graph tensor shapes match the bucket.
        assert_eq!((p.graph_tensors[0].rows, p.graph_tensors[0].cols), (256, 64));
        assert_eq!((p.graph_tensors[8].rows, p.graph_tensors[8].cols), (128, 16));
    }

    #[test]
    fn narrow_bucket_is_lossy_but_loud_and_strict_rejects() {
        let g = small();
        let mut b = bucket();
        b.w_near = 2; // avg near degree ≈ 20 → guaranteed truncation
        let p = pad_graph(&g, b).unwrap();
        assert!(p.truncated > 0, "w_near=2 must truncate the near adjacency");
        let err = pad_graph_strict(&g, b).unwrap_err().to_string();
        assert!(err.contains("truncat"), "strict error must name truncation: {err}");
        assert!(err.contains("near=2"), "strict error must report widths: {err}");
    }

    #[test]
    fn strict_padding_succeeds_when_bucket_fits() {
        // Handcrafted graph whose max degrees are known exactly, so the
        // bucket provably covers every row.
        let near = Csr::from_triplets(3, 3, &[(0, 1, 1.0), (1, 0, 1.0), (1, 2, 1.0), (2, 1, 1.0)]);
        let pins = Csr::from_triplets(2, 3, &[(0, 0, 1.0), (0, 1, 1.0), (1, 2, 1.0)]);
        let g = HeteroGraph {
            id: 0,
            n_cells: 3,
            n_nets: 2,
            pinned: pins.transpose(),
            near,
            pins,
            x_cell: Matrix::zeros(3, 4),
            x_net: Matrix::zeros(2, 4),
            y_cell: Matrix::zeros(3, 1),
        };
        let b = Bucket {
            n_cell: 4,
            n_net: 4,
            w_near: 2,
            w_pins: 2,
            w_pinned: 2,
            hidden: 8,
            k_cell: 2,
            k_net: 2,
        };
        let p = pad_graph_strict(&g, b).unwrap();
        assert_eq!(p.truncated, 0);
        assert_eq!(p.graph_tensors.len(), 12);
    }

    #[test]
    fn oversize_graph_rejected() {
        let g = small();
        let mut b = bucket();
        b.n_cell = 10;
        assert!(pad_graph(&g, b).is_err());
    }

    #[test]
    fn ell_indices_in_range() {
        let g = small();
        let p = pad_graph(&g, bucket()).unwrap();
        // near idx < n_cell cap; pins idx (cols = cells) < n_cell.
        for &v in &p.graph_tensors[0].data {
            assert!(v >= 0.0 && (v as usize) < 256);
        }
        for &v in &p.graph_tensors[8].data {
            assert!(v >= 0.0 && (v as usize) < 256);
        }
    }
}
