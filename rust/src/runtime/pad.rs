//! Graph → static-bucket padding for the AOT artifacts.
//!
//! The HLO artifacts have fixed shapes (see python/compile/graph_spec.py
//! and each artifact's `.meta` bucket note). This module pads a real
//! heterograph into the bucket: ELL-encodes each adjacency (destination-
//! major forward + source-major transpose), zero-pads features/labels and
//! produces the cell mask used by the masked loss.

use crate::graph::{Csr, HeteroGraph};
use crate::tensor::Matrix;
use anyhow::{bail, Result};

/// Static bucket description (parsed from the artifact meta's bucket note).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub n_cell: usize,
    pub n_net: usize,
    pub w_near: usize,
    pub w_pins: usize,
    pub w_pinned: usize,
    pub hidden: usize,
    pub k_cell: usize,
    pub k_net: usize,
}

impl Bucket {
    /// Parse from a meta note like
    /// `bucket n_cell=256 n_net=128 w_near=64 w_pins=16 w_pinned=16 hidden=64 k_cell=8 k_net=8`.
    pub fn parse_note(note: &str) -> Result<Bucket> {
        let mut map = std::collections::BTreeMap::new();
        for tok in note.split_whitespace() {
            if let Some((k, v)) = tok.split_once('=') {
                map.insert(k.to_string(), v.parse::<usize>()?);
            }
        }
        let get = |k: &str| -> Result<usize> {
            map.get(k).copied().ok_or_else(|| anyhow::anyhow!("bucket note missing '{k}'"))
        };
        Ok(Bucket {
            n_cell: get("n_cell")?,
            n_net: get("n_net")?,
            w_near: get("w_near")?,
            w_pins: get("w_pins")?,
            w_pinned: get("w_pinned")?,
            hidden: get("hidden")?,
            k_cell: get("k_cell")?,
            k_net: get("k_net")?,
        })
    }
}

/// ELL encoding of one adjacency: idx/val as f32 matrices (`rows × width`),
/// plus how many entries were truncated by the width cap.
#[derive(Clone, Debug)]
pub struct Ell {
    pub idx: Matrix,
    pub val: Matrix,
    pub truncated: usize,
}

/// ELL-encode a CSR into `rows_cap × width`, truncating over-wide rows.
/// Index slots of padding entries point at row 0 with value 0 (harmless).
pub fn to_ell(adj: &Csr, rows_cap: usize, width: usize) -> Result<Ell> {
    if adj.rows > rows_cap {
        bail!("adjacency rows {} exceed bucket capacity {}", adj.rows, rows_cap);
    }
    let mut idx = Matrix::zeros(rows_cap, width);
    let mut val = Matrix::zeros(rows_cap, width);
    let mut truncated = 0usize;
    for r in 0..adj.rows {
        let range = adj.row_range(r);
        let deg = range.len();
        if deg > width {
            truncated += deg - width;
        }
        for (slot, p) in range.take(width).enumerate() {
            *idx.at_mut(r, slot) = adj.indices[p] as f32;
            *val.at_mut(r, slot) = adj.values[p];
        }
    }
    Ok(Ell { idx, val, truncated })
}

/// A heterograph padded into an artifact bucket, ready to feed PJRT.
#[derive(Clone, Debug)]
pub struct PaddedGraph {
    pub bucket: Bucket,
    /// The 12 graph tensors in `model.GRAPH_KEYS` order.
    pub graph_tensors: Vec<Matrix>,
    pub x_cell: Matrix,
    pub x_net: Matrix,
    pub y_cell: Matrix,
    pub cell_mask: Matrix,
    /// Total ELL truncation across all six encodings.
    pub truncated: usize,
    /// Real node counts before padding.
    pub real_cells: usize,
    pub real_nets: usize,
}

/// Pad a graph (with pre-normalised adjacencies) into the bucket.
///
/// Normalisation mirrors the training path: GCN-norm on `near`, row mean
/// on `pins`/`pinned`.
pub fn pad_graph(g: &HeteroGraph, bucket: Bucket) -> Result<PaddedGraph> {
    if g.n_cells > bucket.n_cell || g.n_nets > bucket.n_net {
        bail!(
            "graph ({} cells, {} nets) exceeds bucket ({}, {})",
            g.n_cells,
            g.n_nets,
            bucket.n_cell,
            bucket.n_net
        );
    }
    let mut near = g.near.clone();
    near.normalize_gcn();
    let mut pinned = g.pinned.clone();
    pinned.normalize_rows();
    let mut pins = g.pins.clone();
    pins.normalize_rows();

    // Forward (destination-major) and transposed (source-major) ELLs.
    let near_f = to_ell(&near, bucket.n_cell, bucket.w_near)?;
    let near_t = to_ell(&near.transpose(), bucket.n_cell, bucket.w_near)?;
    let pinned_f = to_ell(&pinned, bucket.n_cell, bucket.w_pinned)?;
    let pinned_t = to_ell(&pinned.transpose(), bucket.n_net, bucket.w_pins)?;
    let pins_f = to_ell(&pins, bucket.n_net, bucket.w_pins)?;
    let pins_t = to_ell(&pins.transpose(), bucket.n_cell, bucket.w_pinned)?;
    let truncated = near_f.truncated
        + near_t.truncated
        + pinned_f.truncated
        + pinned_t.truncated
        + pins_f.truncated
        + pins_t.truncated;

    let pad_rows = |m: &Matrix, rows: usize| -> Matrix {
        let mut out = Matrix::zeros(rows, m.cols);
        for r in 0..m.rows {
            out.row_mut(r).copy_from_slice(m.row(r));
        }
        out
    };
    let mut cell_mask = Matrix::zeros(bucket.n_cell, 1);
    for r in 0..g.n_cells {
        cell_mask.data[r] = 1.0;
    }
    // GRAPH_KEYS order: near idx/val/idx_t/val_t, pinned ..., pins ...
    let graph_tensors = vec![
        near_f.idx, near_f.val, near_t.idx, near_t.val,
        pinned_f.idx, pinned_f.val, pinned_t.idx, pinned_t.val,
        pins_f.idx, pins_f.val, pins_t.idx, pins_t.val,
    ];
    Ok(PaddedGraph {
        bucket,
        graph_tensors,
        x_cell: pad_rows(&g.x_cell, bucket.n_cell),
        x_net: pad_rows(&g.x_net, bucket.n_net),
        y_cell: pad_rows(&g.y_cell, bucket.n_cell),
        cell_mask,
        truncated,
        real_cells: g.n_cells,
        real_nets: g.n_nets,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{generate_graph, GraphSpec};
    use crate::util::rng::Rng;

    fn bucket() -> Bucket {
        Bucket {
            n_cell: 256,
            n_net: 128,
            w_near: 64,
            w_pins: 16,
            w_pinned: 16,
            hidden: 64,
            k_cell: 8,
            k_net: 8,
        }
    }

    fn small() -> HeteroGraph {
        let mut rng = Rng::new(1);
        generate_graph(
            &GraphSpec {
                n_cells: 200,
                n_nets: 100,
                target_near: 4000,
                target_pins: 300,
                d_cell: 16,
                d_net: 16,
            },
            0,
            &mut rng,
        )
    }

    #[test]
    fn parse_bucket_note() {
        let b = Bucket::parse_note(
            "bucket n_cell=256 n_net=128 w_near=64 w_pins=16 w_pinned=16 hidden=64 k_cell=8 k_net=8",
        )
        .unwrap();
        assert_eq!(b, bucket());
        assert!(Bucket::parse_note("bucket n_cell=1").is_err());
    }

    #[test]
    fn ell_round_trip_dense() {
        let adj = Csr::from_triplets(3, 5, &[(0, 1, 2.0), (0, 4, 3.0), (2, 0, 1.0)]);
        let ell = to_ell(&adj, 4, 3).unwrap();
        assert_eq!(ell.truncated, 0);
        assert_eq!(ell.idx.at(0, 0), 1.0);
        assert_eq!(ell.val.at(0, 1), 3.0);
        assert_eq!(ell.val.at(1, 0), 0.0); // empty row padded
        assert_eq!(ell.val.at(3, 0), 0.0); // padded row
    }

    #[test]
    fn ell_truncation_counted() {
        let t: Vec<_> = (0..10).map(|c| (0usize, c, 1.0f32)).collect();
        let adj = Csr::from_triplets(1, 10, &t);
        let ell = to_ell(&adj, 1, 4).unwrap();
        assert_eq!(ell.truncated, 6);
    }

    #[test]
    fn pad_graph_shapes_and_mask() {
        let g = small();
        let p = pad_graph(&g, bucket()).unwrap();
        assert_eq!(p.graph_tensors.len(), 12);
        assert_eq!((p.x_cell.rows, p.x_cell.cols), (256, 16));
        assert_eq!((p.x_net.rows, p.x_net.cols), (128, 16));
        assert_eq!(p.cell_mask.data.iter().filter(|&&v| v == 1.0).count(), 200);
        assert_eq!(p.real_cells, 200);
        // Graph tensor shapes match the bucket.
        assert_eq!((p.graph_tensors[0].rows, p.graph_tensors[0].cols), (256, 64));
        assert_eq!((p.graph_tensors[8].rows, p.graph_tensors[8].cols), (128, 16));
    }

    #[test]
    fn oversize_graph_rejected() {
        let g = small();
        let mut b = bucket();
        b.n_cell = 10;
        assert!(pad_graph(&g, b).is_err());
    }

    #[test]
    fn ell_indices_in_range() {
        let g = small();
        let p = pad_graph(&g, bucket()).unwrap();
        // near idx < n_cell cap; pins idx (cols = cells) < n_cell.
        for &v in &p.graph_tensors[0].data {
            assert!(v >= 0.0 && (v as usize) < 256);
        }
        for &v in &p.graph_tensors[8].data {
            assert!(v >= 0.0 && (v as usize) < 256);
        }
    }
}
