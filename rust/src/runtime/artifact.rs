//! Artifact registry: discovers `*.hlo.txt` + `*.meta` pairs in the
//! artifacts directory and validates feed shapes against the metadata
//! `aot.py` records.
//!
//! Meta format (line-oriented, written by python/compile/aot.py):
//! ```text
//! input x_cell 128 64
//! input x_net 96 64
//! output y_cell 128 64
//! note near spmm dim=64
//! ```

use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Shape metadata of one artifact.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ArtifactMeta {
    pub name: String,
    /// (name, dims) in positional order.
    pub inputs: Vec<(String, Vec<i64>)>,
    pub outputs: Vec<(String, Vec<i64>)>,
    pub notes: Vec<String>,
}

impl ArtifactMeta {
    pub fn parse(name: &str, text: &str) -> Result<ArtifactMeta> {
        let mut meta = ArtifactMeta { name: name.to_string(), ..Default::default() };
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let mut toks = line.split_whitespace();
            let kind = toks.next().unwrap();
            match kind {
                "input" | "output" => {
                    let tname = toks
                        .next()
                        .with_context(|| format!("{name}.meta:{}: missing name", lineno + 1))?
                        .to_string();
                    let dims: Vec<i64> = toks
                        .map(|t| t.parse::<i64>())
                        .collect::<std::result::Result<_, _>>()
                        .with_context(|| format!("{name}.meta:{}: bad dims", lineno + 1))?;
                    if kind == "input" {
                        meta.inputs.push((tname, dims));
                    } else {
                        meta.outputs.push((tname, dims));
                    }
                }
                "note" => meta.notes.push(toks.collect::<Vec<_>>().join(" ")),
                other => bail!("{name}.meta:{}: unknown record '{other}'", lineno + 1),
            }
        }
        Ok(meta)
    }

    /// Check a positional feed of matrix shapes against the metadata.
    pub fn validate_feed(&self, shapes: &[(usize, usize)]) -> Result<()> {
        if shapes.len() != self.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.name,
                self.inputs.len(),
                shapes.len()
            );
        }
        for (i, ((iname, dims), &(r, c))) in self.inputs.iter().zip(shapes).enumerate() {
            let want: Vec<i64> = dims.clone();
            let got = vec![r as i64, c as i64];
            if want != got {
                bail!("{}: input {i} ({iname}) wants {want:?}, got {got:?}", self.name);
            }
        }
        Ok(())
    }
}

/// Registry over an artifacts directory.
#[derive(Debug, Default)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    metas: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactRegistry {
    /// Scan `dir` for `<name>.hlo.txt` files (meta files optional but
    /// recommended).
    pub fn scan(dir: &Path) -> Result<ArtifactRegistry> {
        let mut metas = BTreeMap::new();
        if dir.is_dir() {
            for entry in std::fs::read_dir(dir).context("reading artifacts dir")? {
                let path = entry?.path();
                let fname = path.file_name().and_then(|s| s.to_str()).unwrap_or("");
                if let Some(name) = fname.strip_suffix(".hlo.txt") {
                    let meta_path = dir.join(format!("{name}.meta"));
                    let meta = if meta_path.exists() {
                        ArtifactMeta::parse(name, &std::fs::read_to_string(&meta_path)?)?
                    } else {
                        ArtifactMeta { name: name.to_string(), ..Default::default() }
                    };
                    metas.insert(name.to_string(), meta);
                }
            }
        }
        Ok(ArtifactRegistry { dir: dir.to_path_buf(), metas })
    }

    pub fn names(&self) -> Vec<&str> {
        self.metas.keys().map(|s| s.as_str()).collect()
    }

    pub fn meta(&self, name: &str) -> Option<&ArtifactMeta> {
        self.metas.get(name)
    }

    pub fn hlo_path(&self, name: &str) -> PathBuf {
        self.dir.join(format!("{name}.hlo.txt"))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.metas.contains_key(name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const META: &str = "\
# example
input x_cell 128 64
input w 64 64
output y 128 64
note spmm near dim=64
";

    #[test]
    fn parse_meta() {
        let m = ArtifactMeta::parse("spmm_near", META).unwrap();
        assert_eq!(m.inputs.len(), 2);
        assert_eq!(m.inputs[0], ("x_cell".to_string(), vec![128, 64]));
        assert_eq!(m.outputs.len(), 1);
        assert_eq!(m.notes, vec!["spmm near dim=64"]);
    }

    #[test]
    fn validate_feed_checks_shapes() {
        let m = ArtifactMeta::parse("t", META).unwrap();
        assert!(m.validate_feed(&[(128, 64), (64, 64)]).is_ok());
        assert!(m.validate_feed(&[(128, 64)]).is_err());
        assert!(m.validate_feed(&[(128, 32), (64, 64)]).is_err());
    }

    #[test]
    fn bad_meta_rejected() {
        assert!(ArtifactMeta::parse("t", "frobnicate x").is_err());
        assert!(ArtifactMeta::parse("t", "input x 12a").is_err());
    }

    #[test]
    fn scan_tempdir() {
        let dir = std::env::temp_dir().join(format!("drcg_art_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("foo.hlo.txt"), "HloModule foo").unwrap();
        std::fs::write(dir.join("foo.meta"), "input a 2 2\noutput b 2 2").unwrap();
        std::fs::write(dir.join("bare.hlo.txt"), "HloModule bare").unwrap();
        let reg = ArtifactRegistry::scan(&dir).unwrap();
        assert!(reg.contains("foo"));
        assert!(reg.contains("bare"));
        assert_eq!(reg.meta("foo").unwrap().inputs.len(), 1);
        assert_eq!(reg.meta("bare").unwrap().inputs.len(), 0);
        assert!(reg.hlo_path("foo").ends_with("foo.hlo.txt"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn scan_missing_dir_is_empty() {
        let reg = ArtifactRegistry::scan(Path::new("/nonexistent/xyz")).unwrap();
        assert!(reg.names().is_empty());
    }
}
