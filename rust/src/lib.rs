//! # DR-CircuitGNN
//!
//! A reproduction of *“DR-CircuitGNN: Training Acceleration of Heterogeneous
//! Circuit Graph Neural Network on GPUs”* (ICS 2025) as a three-layer
//! Rust + JAX + Pallas system:
//!
//! * **Layer 3 (this crate)** — the coordinator: heterogeneous circuit-graph
//!   substrate, the D-ReLU/CBSR sparsification and DR-SpMM kernels with their
//!   cuSPARSE/GNNAdvisor-analog baselines, a hand-differentiated HGNN training
//!   stack, and the paper's §3.4 parallel subgraph pipeline.
//! * **Layer 2 (python/compile/model.py)** — the same HGNN in JAX, AOT-lowered
//!   to HLO text artifacts consumed by [`runtime`].
//! * **Layer 1 (python/compile/kernels/)** — Pallas kernels (interpret mode)
//!   for D-ReLU and DR-SpMM, validated against pure-jnp oracles.
//!
//! Kernel dispatch is unified behind the [`engine`] subsystem: a
//! plan/execute [`engine::SpmmKernel`] trait, a name registry
//! (`"csr" | "gnna" | "dr" | "auto"`), and an [`engine::Engine`] facade
//! with per-edge-type kernel selection. See `docs/ENGINE.md` for the API
//! walkthrough and the per-experiment index mapping every table/figure of
//! the paper to a bench target.
//!
//! Above the engine sits the [`fleet`] subsystem — batched multi-subgraph
//! execution: one engine per subgraph of a design (deduplicated through a
//! content-hash plan cache), per-subgraph train steps on a bounded worker
//! pool, and deterministic gradient reduction. See `docs/FLEET.md`.
//!
//! Two persistence/serving layers close the loop from benchmark binary to
//! resident system: [`engine::PlanStore`] persists kernel plans (and
//! measured K profiles) to disk keyed by adjacency content-hash +
//! engine-configuration signature, so a restarted process warm-starts
//! Alg. 1 stage 1; and the [`serve`] subsystem runs a bounded job queue
//! over one shared disk-backed plan cache. See `docs/SERVE.md`.
//!
//! Designs evolve under the tool: [`graph::delta`] applies engineering
//! change orders (ECOs) bit-identically to a from-scratch rebuild,
//! `EngineBuilder::repair` patches cached kernel plans instead of
//! rebuilding them, and [`fleet::apply_eco`] restages only the fleet
//! partitions an ECO actually touches. See `docs/DELTA.md`.
//!
//! The invariants all of this rests on — documented `unsafe` disjointness
//! contracts, budgeted fan-out, one mutex-poisoning policy, determinism of
//! trace-feeding paths, registry/plan-store exhaustiveness — are machine-
//! checked by the in-tree [`analysis`] pass (`drcg-lint`), with loom /
//! Miri / ThreadSanitizer lanes around the code it polices. See
//! `docs/ANALYSIS.md`.

pub mod analysis;
pub mod bench;
pub mod config;
pub mod datagen;
pub mod engine;
pub mod fleet;
pub mod graph;
pub mod nn;
pub mod runtime;
pub mod sched;
pub mod serve;
pub mod sparse;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
