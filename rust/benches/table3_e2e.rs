//! E7 — regenerates paper **Table 3**: end-to-end performance (init +
//! forward + backward over all three subgraphs) of the parallel DR
//! pipeline vs sequential cuSPARSE and GNNA, dims 64 and 128, for every
//! graph of the three representative designs, plus the averages row.
//!
//! Paper averages @64: 2.71× vs cuSPARSE, 11.10× vs GNNA.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale, table1_graphs};
use dr_circuitgnn::bench::Table;
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::util::math::mean;

fn median_total(
    g: &dr_circuitgnn::graph::HeteroGraph,
    dim: usize,
    engine: &EngineBuilder,
    mode: ScheduleMode,
    reps: usize,
) -> f64 {
    let mut samples: Vec<f64> = (0..reps)
        .map(|r| run_e2e_step(g, dim, engine, mode, 100 + r as u64).total)
        .collect();
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    samples[samples.len() / 2]
}

fn main() {
    let scale = bench_scale();
    let reps = bench_reps().max(3);
    println!("Table 3 — end-to-end speedups (scale {scale}, reps {reps})");
    for dim in [64usize, 128] {
        let mut t = Table::new(
            &format!("dim {dim}"),
            &["design", "graph", "vs cuSPARSE fwd+bwd", "vs GNNA fwd+bwd"],
        );
        let mut v_csr = Vec::new();
        let mut v_gnna = Vec::new();
        for (name, graphs) in table1_graphs(scale) {
            for g in &graphs {
                let base =
                    median_total(g, dim, &EngineBuilder::csr(), ScheduleMode::Sequential, reps);
                let gnna = median_total(
                    g,
                    dim,
                    &EngineBuilder::gnna(GnnaConfig::default()),
                    ScheduleMode::Sequential,
                    reps,
                );
                // Paper's configuration: profiled K (we use the stable k=8
                // optimum region) + the parallel schedule where the machine
                // can actually overlap lanes (single-core boxes would only
                // pay thread overhead — see EXPERIMENTS.md E7).
                let mode = if std::thread::available_parallelism()
                    .map(|n| n.get())
                    .unwrap_or(1)
                    > 1
                {
                    ScheduleMode::Parallel
                } else {
                    ScheduleMode::Sequential
                };
                let ours = median_total(g, dim, &EngineBuilder::dr(8, 8), mode, reps);
                let s_csr = base / ours;
                let s_gnna = gnna / ours;
                v_csr.push(s_csr);
                v_gnna.push(s_gnna);
                t.row(&[
                    name.clone(),
                    format!("graph{}", g.id),
                    format!("{s_csr:.2}"),
                    format!("{s_gnna:.2}"),
                ]);
            }
        }
        t.row(&[
            "Average".into(),
            "-".into(),
            format!("{:.2}", mean(&v_csr)),
            format!("{:.2}", mean(&v_gnna)),
        ]);
        t.print();
        println!("paper averages: dim 64 → 2.71 / 11.10; dim 128 → 2.44 / 10.42\n");
    }
}
