//! E6 — regenerates paper **Fig. 10**: training with varying K_net and
//! K_cell on Mini-CircuitNet — correlation scores (top row) and training
//! speedup over the DGL/cuSPARSE and GNNA baselines (bottom row).
//!
//! Expected shape (paper): scores stable across the K range; speedup
//! peaks in K ∈ [2, 8] (up to 1.65×/1.88× vs DGL fwd/bwd) and decays as
//! K approaches 32/64.

use dr_circuitgnn::bench::Table;
use dr_circuitgnn::datagen::mini_circuitnet;
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::train::{TrainConfig, Trainer};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = std::env::var("DRCG_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.12)
        .min(1.0);
    // At least one design must land in the 5:1 test split (d % 6 == 5).
    let n_designs = env_usize("DRCG_BENCH_DESIGNS", 7).max(6);
    let epochs = env_usize("DRCG_BENCH_EPOCHS", 5);
    println!(
        "Fig. 10 — K sweep on Mini-CircuitNet ({n_designs} designs, {epochs} epochs, scale {scale})"
    );
    let (train, test) = mini_circuitnet(n_designs, scale, 21);
    let cfg = TrainConfig {
        epochs,
        lr: 2e-4,
        weight_decay: 1e-5,
        hidden: 64,
        seed: 2,
        parallel: false,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    };

    // Baselines: identical model trained through the dense engines.
    let (_m, base_csr) = Trainer::train_dr(&train, &test, &EngineBuilder::csr(), &cfg);
    let (_m, base_gnna) =
        Trainer::train_dr(&train, &test, &EngineBuilder::gnna(GnnaConfig::default()), &cfg);
    println!(
        "baselines: cuSPARSE {:.1}s, GNNA {:.1}s",
        base_csr.train_seconds, base_gnna.train_seconds
    );

    let mut t = Table::new(
        "varying K (K_cell = K_net = K)",
        &["K", "Pearson", "Spear.", "Ken.", "MAE", "RMSE", "train s", "speedup vs DGL", "vs GNNA"],
    );
    for k in [2usize, 4, 8, 16, 32, 64] {
        let (_m, r) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(k, k), &cfg);
        t.row(&[
            k.to_string(),
            format!("{:.3}", r.test_scores.pearson),
            format!("{:.3}", r.test_scores.spearman),
            format!("{:.3}", r.test_scores.kendall),
            format!("{:.3}", r.test_scores.mae),
            format!("{:.3}", r.test_scores.rmse),
            format!("{:.1}", r.train_seconds),
            format!("{:.2}x", base_csr.train_seconds / r.train_seconds),
            format!("{:.2}x", base_gnna.train_seconds / r.train_seconds),
        ]);
    }
    t.print();

    // Asymmetric K (the paper sweeps K_net and K_cell separately).
    let mut t2 = Table::new(
        "asymmetric K (K_cell, K_net)",
        &["K_cell", "K_net", "Spear.", "train s", "speedup vs DGL"],
    );
    for (kc, kn) in [(2, 8), (8, 2), (4, 16), (16, 4)] {
        let (_m, r) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(kc, kn), &cfg);
        t2.row(&[
            kc.to_string(),
            kn.to_string(),
            format!("{:.3}", r.test_scores.spearman),
            format!("{:.1}", r.train_seconds),
            format!("{:.2}x", base_csr.train_seconds / r.train_seconds),
        ]);
    }
    t2.print();
    println!("paper: speedup up to 1.65×/1.88× vs DGL in K∈[2,8]; scores stable across K");
}
