//! E15 — **Fig. 15 (repo extension)**: million-node training via window
//! sampling + activation checkpointing (ISSUE 10). The Full design tier
//! (`full_design`, ≈10⁶ cells / ≈5·10⁷ near edges at scale 1.0) cannot be
//! trained full-graph under a realistic memory budget — staging every
//! partition's features, adjacencies and activation caches at once blows
//! the budget — but the window-sampled trainer touches only
//! `count × cells`-sized subgraphs per design per epoch, and checkpointing
//! caps live activations at one layer.
//!
//! Two measurements:
//! * a *measured* sweep on a scaled-down Full tier: median full-graph fleet
//!   step time + peak staging proxy vs the window-sampled round (sample +
//!   owned build + step), with the window round's loss asserted finite and
//!   its staging proxy asserted strictly smaller;
//! * a *paper-scale* extrapolation from the `full_design(1.0)` spec
//!   numbers: byte proxies for full-graph vs sampled staging against a
//!   2 GiB activation/staging budget — full must not fit, sampled must.
//!
//! Run: `cargo bench --bench fig15_window_scale` (env `DRCG_BENCH_SCALE`,
//! `DRCG_BENCH_REPS` as usual). Emits `BENCH_fig15_window_scale.json`.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale};
use dr_circuitgnn::bench::{fmt_speedup, write_bench_json, Json, Table};
use dr_circuitgnn::datagen::{full_design, generate_design, sample_windows, DesignSpec};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::fleet::Fleet;
use dr_circuitgnn::graph::HeteroGraph;
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::util::pool::num_threads;
use dr_circuitgnn::util::rng::Rng;

const HIDDEN: usize = 32;
/// Paper-scale model width (§4.1) used for the extrapolated proxies.
const PAPER_HIDDEN: usize = 64;
/// Staging/activation budget for the extrapolation: 2 GiB.
const BUDGET_BYTES: f64 = 2.0 * 1024.0 * 1024.0 * 1024.0;

fn main() {
    // The Full tier is ≈10⁶ cells at scale 1.0 — the measured sweep runs a
    // small slice of it (the *shape* is what matters: 8 partitions, near
    // edges ≈ 50× cells), the extrapolation uses the 1.0 spec numbers.
    let scale = (bench_scale() * 0.1).clamp(0.002, 0.05);
    let reps = bench_reps().max(3);
    let spec = full_design(scale);
    let graphs = generate_design(&spec);
    let total_cells: usize = graphs.iter().map(|g| g.n_cells).sum();
    println!(
        "Fig. 15 — window-sampled training vs full-graph on the Full tier \
         (scale {scale}, {} partitions, {total_cells} cells, {} hw threads)",
        graphs.len(),
        num_threads()
    );

    let g0 = &graphs[0];
    let mut rng = Rng::new(42);
    let model0 = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, HIDDEN, &mut rng);
    let builder = Fleet::builder(EngineBuilder::dr(8, 8).parallel(true)).workers(4);

    // --- Full-graph reference: one fleet over all partitions. -----------
    let fleet = builder.clone().build(&graphs);
    let full_peak: f64 = graphs.iter().map(|g| measured_bytes(g, HIDDEN, false)).sum();
    let mut full_samples = Vec::with_capacity(reps);
    let mut full_loss = f64::NAN;
    for _ in 0..reps {
        let mut model = model0.clone();
        let mut opt = Adam::new(2e-4, 1e-5);
        let t0 = std::time::Instant::now();
        full_loss = fleet.step(&mut model, &mut opt).loss;
        full_samples.push(t0.elapsed().as_secs_f64());
    }
    let full_step = median(&mut full_samples);

    // --- Window-sampled round: sample + owned build + checkpointed step.
    // The round is the honest unit of work window training pays per design
    // per epoch — sampling and planning are part of it, not amortizable,
    // because every epoch cuts fresh windows.
    let count = 2usize;
    let cells = (g0.n_cells / 4).max(8);
    let mut sampled_samples = Vec::with_capacity(reps);
    let mut sampled_loss = f64::NAN;
    let mut sampled_peak = 0f64;
    for rep in 0..reps {
        let mut model = model0.clone();
        model.set_checkpoint(true);
        let mut opt = Adam::new(2e-4, 1e-5);
        let t0 = std::time::Instant::now();
        let mut windows: Vec<HeteroGraph> = Vec::new();
        for g in &graphs {
            windows.extend(sample_windows(g, count, cells, 42, rep));
        }
        for (i, w) in windows.iter_mut().enumerate() {
            w.id = i;
        }
        let peak: f64 = windows.iter().map(|w| measured_bytes(w, HIDDEN, true)).sum();
        let wfleet = builder.clone().build_owned(windows);
        sampled_loss = wfleet.step(&mut model, &mut opt).loss;
        sampled_samples.push(t0.elapsed().as_secs_f64());
        sampled_peak = sampled_peak.max(peak);
    }
    let sampled_step = median(&mut sampled_samples);

    assert!(full_loss.is_finite() && sampled_loss.is_finite());
    assert!(
        sampled_peak < full_peak,
        "window staging ({sampled_peak:.0} B) must undercut full-graph staging \
         ({full_peak:.0} B)"
    );

    let mut t = Table::new(
        &format!("full-graph vs window-sampled step ({}, {total_cells} cells)", spec.name),
        &["mode", "median step ms", "vs full", "staging proxy MB", "loss"],
    );
    t.row(&[
        "full-graph".into(),
        format!("{:.1}", full_step * 1e3),
        "1.00x".into(),
        format!("{:.1}", full_peak / 1e6),
        format!("{full_loss:.6}"),
    ]);
    t.row(&[
        format!("window {count}x{cells} +ckpt"),
        format!("{:.1}", sampled_step * 1e3),
        fmt_speedup(full_step, sampled_step),
        format!("{:.1}", sampled_peak / 1e6),
        format!("{sampled_loss:.6}"),
    ]);
    t.print();

    // --- Paper-scale extrapolation from the spec numbers. ---------------
    let paper = full_design(1.0);
    let paper_cells: usize = paper.graphs.iter().map(|g| g.n_cells).sum();
    let paper_full = spec_bytes_full(&paper, PAPER_HIDDEN, false);
    // Window mode at paper scale: 2 windows of 20k cells per partition,
    // checkpointed — edge/net loads scaled from the spec's per-cell rates.
    let (w_count, w_cells) = (2usize, 20_000usize);
    let paper_sampled = spec_bytes_windows(&paper, w_count, w_cells, PAPER_HIDDEN, true);
    let full_fits = paper_full <= BUDGET_BYTES;
    let sampled_fits = paper_sampled <= BUDGET_BYTES;
    println!(
        "paper scale ({paper_cells} cells): full-graph staging {:.2} GB vs window \
         {w_count}x{w_cells} + checkpoint {:.2} GB against a {:.0} GiB budget — \
         full fits: {full_fits}, sampled fits: {sampled_fits}",
        paper_full / 1e9,
        paper_sampled / 1e9,
        BUDGET_BYTES / (1024.0 * 1024.0 * 1024.0)
    );
    assert!(
        !full_fits,
        "full-graph staging of the Full tier ({paper_full:.0} B) should exceed the \
         {BUDGET_BYTES:.0} B budget — that is the problem window sampling solves"
    );
    assert!(
        sampled_fits,
        "window-sampled staging ({paper_sampled:.0} B) must fit the {BUDGET_BYTES:.0} B budget"
    );

    let json = Json::obj()
        .set("bench", "fig15_window_scale")
        .set("scale", scale)
        .set("reps", reps)
        .set("design", spec.name.clone())
        .set("partitions", graphs.len())
        .set("total_cells", total_cells)
        .set("full_step_s", full_step)
        .set("sampled_step_s", sampled_step)
        .set("full_peak_bytes", full_peak)
        .set("sampled_peak_bytes", sampled_peak)
        .set("window", format!("{count}x{cells}"))
        .set("checkpoint", true)
        .set(
            "paper_scale",
            Json::obj()
                .set("cells", paper_cells)
                .set("budget_bytes", BUDGET_BYTES)
                .set("full_bytes", paper_full)
                .set("sampled_bytes", paper_sampled)
                .set("window", format!("{w_count}x{w_cells}"))
                .set("full_fits_budget", full_fits)
                .set("sampled_fits_budget", sampled_fits),
        );
    write_bench_json("fig15_window_scale", &json);
}

fn median(xs: &mut [f64]) -> f64 {
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[xs.len() / 2]
}

/// Peak-memory proxy of training one graph, in bytes, from its *actual*
/// matrices and adjacencies: staged features/labels + CSR/CSC adjacency
/// storage + live activation working set. Checkpointing caps the working
/// set at one layer's activations; the default forward keeps every
/// layer's caches alive until backward.
fn measured_bytes(g: &HeteroGraph, hidden: usize, checkpoint: bool) -> f64 {
    let feats = (g.x_cell.data.len() + g.x_net.data.len() + g.y_cell.data.len()) * 4;
    // ~12 B/edge (u32 index + f32 value + amortised row pointers), forward
    // + transpose directions for each of the three edge types.
    let edges = 2 * (g.near.nnz() + g.pins.nnz() + g.pinned.nnz());
    let acts = activation_bytes(g.n_cells, g.n_nets, hidden, checkpoint);
    (feats + edges * 12) as f64 + acts
}

/// Activation working set: matrices of shape (n_cells|n_nets) × hidden
/// held live across the step. Uncheckpointed, the two conv layers + lin +
/// ReLU masks keep ≈8 such per node type; checkpointed, only the layer
/// boundaries (≈2) persist while one layer recomputes at a time.
fn activation_bytes(n_cells: usize, n_nets: usize, hidden: usize, checkpoint: bool) -> f64 {
    let layers = if checkpoint { 2 } else { 8 };
    ((n_cells + n_nets) * hidden * 4 * layers) as f64
}

/// Spec-level proxy for staging a whole design full-graph: every
/// partition's features, adjacencies (target edge counts) and activation
/// working sets live at once — the fleet stages all subgraphs of a design
/// before executing.
fn spec_bytes_full(spec: &DesignSpec, hidden: usize, checkpoint: bool) -> f64 {
    spec.graphs
        .iter()
        .map(|g| {
            let feats = (g.n_cells * g.d_cell + g.n_nets * g.d_net + g.n_cells) * 4;
            // near (+csc) and pins (+pinned, each with csc).
            let edges = 2 * g.target_near + 4 * g.target_pins;
            feats as f64
                + (edges * 12) as f64
                + activation_bytes(g.n_cells, g.n_nets, hidden, checkpoint)
        })
        .sum()
}

/// Spec-level proxy for one epoch's window-sampled staging: per partition,
/// `count` windows of `cells` cells with edge/net loads scaled from the
/// partition's per-cell rates.
fn spec_bytes_windows(
    spec: &DesignSpec,
    count: usize,
    cells: usize,
    hidden: usize,
    checkpoint: bool,
) -> f64 {
    spec.graphs
        .iter()
        .map(|g| {
            let frac = (cells.min(g.n_cells)) as f64 / g.n_cells as f64;
            let w_cells = cells.min(g.n_cells);
            let w_nets = (g.n_nets as f64 * frac).ceil() as usize;
            let feats = (w_cells * g.d_cell + w_nets * g.d_net + w_cells) * 4;
            let edges =
                (2.0 * g.target_near as f64 * frac + 4.0 * g.target_pins as f64 * frac) as usize;
            count as f64
                * (feats as f64
                    + (edges * 12) as f64
                    + activation_bytes(w_cells, w_nets, hidden, checkpoint))
        })
        .sum()
}
