//! E3 — regenerates paper **Fig. 4**: node-degree distributions of the
//! three subgraphs (pins / near / pinned) of an example CircuitNet graph.
//!
//! Expected shape: `near` peaked around ~50 with a tail past 250 (at full
//! scale); `pins`/`pinned` concentrated at 2–4 with a power-law tail.

use dr_circuitgnn::bench::workloads::{bench_scale, table1_graphs};
use dr_circuitgnn::bench::Table;
use dr_circuitgnn::graph::stats::{DegreeHistogram, ImbalanceStats};
use dr_circuitgnn::graph::EdgeType;

fn main() {
    let scale = bench_scale();
    let designs = table1_graphs(scale);
    let (name, graphs) = &designs[1]; // 2216-RISCY, like the paper example
    let g = &graphs[0];
    println!("Fig. 4 — degree distributions: design {name} graph 0 (scale {scale})\n");
    let mut t = Table::new(
        "degree summary",
        &["edge", "rows", "avg", "mode≈", "max", "p(deg≥4·avg)", "imbalance", "cv"],
    );
    for edge in [EdgeType::Pins, EdgeType::Near, EdgeType::Pinned] {
        let adj = g.adj(edge);
        let hist = DegreeHistogram::of(adj, 2);
        let imb = ImbalanceStats::of(adj);
        t.row(&[
            edge.name().to_string(),
            adj.rows.to_string(),
            format!("{:.1}", hist.avg_degree),
            hist.mode_degree().to_string(),
            hist.max_degree.to_string(),
            format!("{:.4}", hist.tail_fraction((4.0 * hist.avg_degree) as usize)),
            format!("{:.1}", imb.imbalance),
            format!("{:.2}", imb.cv),
        ]);
        println!("{:<7} {}", edge.name(), hist.sparkline(64));
    }
    t.print();

    // The Fig. 4 qualitative claims, asserted:
    let near = ImbalanceStats::of(g.adj(EdgeType::Near));
    let pins = ImbalanceStats::of(g.adj(EdgeType::Pins));
    assert!(near.avg_degree > 8.0 * pins.avg_degree, "near must be much denser than pins");
    assert!(pins.imbalance > 3.0, "pins must have evil rows (power-law tail)");
    println!("fig4 shape checks passed: near dense+spread, pins/pinned low+heavy-tailed");
}
