//! E2 — regenerates paper **Fig. 2**: training-time breakdown of the three
//! modules in one HeteroConv layer (SageConv-pinned, SageConv-pins,
//! GraphConv-near), showing SpMM's share of each module's runtime.
//!
//! Paper: SpMM ≈ 62.4% / 64.5% / 25.4% of the three modules' forward time.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale, embedding, table1_graphs};
use dr_circuitgnn::bench::{measure, Table};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::nn::{GraphConv, SageConv};
use dr_circuitgnn::util::rng::Rng;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps();
    let dim = 64usize;
    let designs = table1_graphs(scale);
    let (name, graphs) = &designs[1]; // medium design
    let g = &graphs[0];
    println!("Fig. 2 — module time breakdown: {name} graph 0, dim {dim} (scale {scale})");

    let mut rng = Rng::new(3);
    let x_cell = embedding(g.n_cells, dim, 1);
    let x_net = embedding(g.n_nets, dim, 2);

    let mut t = Table::new(
        "one HeteroConv layer, forward",
        &["module", "edge", "SpMM ms", "dense ms", "total ms", "SpMM share"],
    );
    let mut shares = Vec::new();
    // One cuSPARSE-analog engine per graph: normalisation + plans built once.
    let engine = EngineBuilder::csr().build(g);
    for (module, edge) in [
        ("SageConv", EdgeType::Pinned),
        ("SageConv", EdgeType::Pins),
        ("GraphConv", EdgeType::Near),
    ] {
        let x_src = match edge {
            EdgeType::Pinned => &x_net,
            _ => &x_cell,
        };
        let x_dst = match edge {
            EdgeType::Pins => &x_net,
            _ => &x_cell,
        };
        // SpMM part (the aggregation), through the engine's cached plan.
        let t_spmm = measure(1, reps, || {
            std::hint::black_box(engine.aggregate_with(edge, x_src, None))
        })
        .median;
        // Dense part (the module's linear algebra on the aggregate).
        let (h, _) = engine.aggregate_with(edge, x_src, None);
        let t_dense = if module == "GraphConv" {
            let mut layer = GraphConv::new(dim, dim, &mut rng);
            measure(1, reps, || {
                std::hint::black_box(layer.forward_from_agg(h.clone()));
            })
            .median
        } else {
            let mut layer = SageConv::new(dim, dim, dim, &mut rng);
            measure(1, reps, || {
                std::hint::black_box(layer.forward_from_agg(x_dst, h.clone()));
            })
            .median
        };
        let total = t_spmm + t_dense;
        let share = t_spmm / total;
        shares.push(share);
        t.row(&[
            module.to_string(),
            edge.name().to_string(),
            format!("{:.2}", t_spmm * 1e3),
            format!("{:.2}", t_dense * 1e3),
            format!("{:.2}", total * 1e3),
            format!("{:.1}%", share * 100.0),
        ]);
    }
    t.print();
    println!("paper shares: ~62.4% (SageConv), ~64.5% (SageConv), ~25.4% (GraphConv)");
    println!(
        "note: on this CPU substrate the dense module matmuls cost far more \n\
         relative to SpMM than on the paper's A6000 (tensor cores make the \n\
         dense part nearly free there), so absolute SpMM shares are lower; \n\
         the ordering (near ≫ pins/pinned share) is preserved."
    );
    // Shape check: SpMM is a visible cost in at least the near module.
    assert!(shares.iter().any(|&s| s > 0.08), "SpMM must be a visible cost somewhere");
}
