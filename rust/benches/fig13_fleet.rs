//! E11 — **Fig. 13 (repo extension)**: fleet scaling sweep. The paper's
//! §3.4 result runs a design's independent subgraphs concurrently (CPU
//! multi-thread init + per-stream kernels); this bench measures that at
//! design scale: one full training step over all subgraphs of a design,
//! swept across worker-pool widths, with the engine's §3.4 edge lanes
//! active inside every worker.
//!
//! Also demonstrates (and asserts) the fleet's **shared plan cache**:
//! building the fleet plans Alg. 1 stage 1 once per *unique* subgraph
//! adjacency — a duplicated subgraph costs zero additional plans — and the
//! per-worker-count sweeps build no plans at all. Determinism is asserted
//! too: every worker count produces the same step loss.
//!
//! Run: `cargo bench --bench fig13_fleet` (env `DRCG_BENCH_SCALE`,
//! `DRCG_BENCH_REPS` as usual).

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale};
use dr_circuitgnn::bench::{fmt_speedup, write_bench_json, Json, Table};
use dr_circuitgnn::datagen::{generate_design, table1_designs};
use dr_circuitgnn::engine::{plan_counters, EngineBuilder};
use dr_circuitgnn::fleet::{Fleet, FleetPipeline, FleetSpec};
use dr_circuitgnn::graph::HeteroGraph;
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::sched::ScheduleMode;
use dr_circuitgnn::util::pool::{num_threads, peak_workers, reset_peak_workers};
use dr_circuitgnn::util::rng::Rng;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps().max(3);
    println!(
        "Fig. 13 — fleet scaling sweep (scale {scale}, {} hw threads)",
        num_threads()
    );

    // The largest Table-1 design, plus one duplicated subgraph so the
    // plan-cache dedup is visible in the numbers.
    let spec = table1_designs(scale).into_iter().last().expect("table1 designs");
    let mut graphs = generate_design(&spec);
    graphs.push(graphs[0].clone());
    let n_subgraphs = graphs.len();
    let unique = n_subgraphs - 1;

    let c0 = plan_counters();
    let fleet1 = Fleet::builder(EngineBuilder::dr(8, 8).parallel(true)).workers(1).build(&graphs);
    let built = plan_counters().since(&c0);
    assert_eq!(
        fleet1.cache_stats().unique(),
        unique,
        "duplicated subgraph must hit the plan cache"
    );
    assert_eq!(
        built.plans,
        3 * unique,
        "plan once per unique subgraph (3 edge types), not per subgraph"
    );
    println!(
        "plan cache: {} subgraphs → {} unique adjacencies → {} plans ({} hits)",
        n_subgraphs,
        unique,
        built.plans,
        fleet1.cache_stats().hits
    );

    let g0 = &graphs[0];
    let mut rng = Rng::new(42);
    let model0 = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 32, &mut rng);

    let mut worker_counts = vec![1usize, 2, 4, 8];
    worker_counts.retain(|&w| w == 1 || w <= 2 * num_threads());

    let budget = num_threads();
    let mut t = Table::new(
        &format!("fleet step time vs workers ({}, {} subgraphs)", spec.name, n_subgraphs),
        &["workers", "median step ms", "speedup vs 1", "step loss", "peak thr / budget"],
    );
    let mut base_ms = 0f64;
    let mut base_loss = f64::NAN;
    let mut json_sweep = Vec::new();
    for &workers in &worker_counts {
        let c1 = plan_counters();
        let fleet = Fleet::builder(EngineBuilder::dr(8, 8).parallel(true))
            .workers(workers)
            .build(&graphs);
        // Re-building the fleet re-plans its unique subgraphs only; the
        // timed steps below must build none.
        assert_eq!(plan_counters().since(&c1).plans, 3 * unique);

        let mut samples = Vec::with_capacity(reps);
        let mut loss = f64::NAN;
        reset_peak_workers();
        for _ in 0..reps {
            // Fresh model/optimizer per rep: every worker count times the
            // exact same first step and must produce the same loss.
            let mut model = model0.clone();
            let mut opt = Adam::new(2e-4, 1e-5);
            let c2 = plan_counters();
            let t0 = std::time::Instant::now();
            loss = fleet.step(&mut model, &mut opt).loss;
            samples.push(t0.elapsed().as_secs_f64());
            assert_eq!(plan_counters().since(&c2).plans, 0, "steps must not plan");
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        // Budget utilization: spawned workers + the driving thread. The
        // cooperative budget guarantees this never exceeds the root cap,
        // whatever worker count × lanes × kernel mix ran above.
        let peak = peak_workers() + 1;
        assert!(
            peak <= budget,
            "thread budget violated: {peak} live threads against a budget of {budget}"
        );
        if workers == 1 {
            base_ms = median;
            base_loss = loss;
        } else {
            assert!(
                (loss - base_loss).abs() < 1e-9,
                "worker count changed numerics: {loss} vs {base_loss}"
            );
        }
        json_sweep.push(
            Json::obj()
                .set("workers", workers)
                .set("median_step_s", median)
                .set("speedup", base_ms / median.max(1e-12))
                .set("step_loss", loss)
                .set("peak_threads", peak)
                .set("budget", budget),
        );
        t.row(&[
            workers.to_string(),
            format!("{:.1}", median * 1e3),
            fmt_speedup(base_ms, median),
            format!("{loss:.6}"),
            format!("{peak}/{budget}"),
        ]);
    }
    t.print();
    println!(
        "deterministic reduction: identical step loss at every worker count \
         (asserted); graph-level workers × §3.4 edge lanes active, all \
         leasing one root budget of {budget} (peak ≤ budget asserted — \
         oversized worker counts borrow threads, they don't oversubscribe)"
    );

    let epoch_json = epoch_pipeline_sweep(scale, reps.clamp(2, 4));
    let json = Json::obj()
        .set("bench", "fig13_fleet")
        .set("scale", scale)
        .set("reps", reps)
        .set("design", spec.name.clone())
        .set("subgraphs", n_subgraphs)
        .set("unique_adjacencies", unique)
        .set(
            "plan_cache",
            Json::obj()
                .set("plans_built", built.plans)
                .set("hits", fleet1.cache_stats().hits),
        )
        .set("worker_sweep", Json::arr(json_sweep))
        .set("epoch_pipeline", epoch_json);
    write_bench_json("fig13_fleet", &json);
}

/// Pipelined-vs-serial epoch sweep (ISSUE 5): train over all three Table-1
/// designs for a few epochs under both epoch schedules, through the same
/// `FleetPipeline` driver the trainer uses — the modes differ only in
/// `ScheduleMode`. Both build their fleets lazily on each design's first
/// visit (through one shared plan cache), so the pipelined run overlaps
/// design N+1's Alg. 1 stage 1 planning + feature staging with design N's
/// execute + optimizer step. Losses are asserted bitwise identical; the
/// timeline's overlap factor is asserted > 1 on multi-core machines.
fn epoch_pipeline_sweep(scale: f64, epochs: usize) -> Json {
    let designs: Vec<Vec<HeteroGraph>> =
        table1_designs(scale).iter().map(generate_design).collect();
    let n_designs = designs.len();
    let g0 = &designs[0][0];
    let mut rng = Rng::new(7);
    let model0 = DrCircuitGnn::new(g0.x_cell.cols, g0.x_net.cols, 32, &mut rng);

    // Partition requests are capped at each graph's cell count (the
    // partitioner warns when it truncates); report the *effective* shape
    // next to the requested one so sweep configs can't silently lie.
    let spec = FleetSpec::parse("4x2").expect("static fleet spec");
    let effective_subgraphs: usize = designs
        .iter()
        .flat_map(|gs| gs.iter())
        .map(|g| spec.effective_parts(g.n_cells))
        .sum();
    println!(
        "fleet spec '{}': effective shape {} subgraphs across {} designs",
        spec.describe(),
        effective_subgraphs,
        n_designs
    );

    let sweep = |mode: ScheduleMode| {
        let pipeline = FleetPipeline::new(
            Fleet::builder(EngineBuilder::dr(8, 8).parallel(true)).spec(&spec),
            designs.iter().map(|gs| gs.as_slice()).collect(),
        );
        let mut model = model0.clone();
        let mut opt = Adam::new(2e-4, 1e-5);
        let mut losses: Vec<f64> = Vec::new();
        let mut epoch_s: Vec<f64> = Vec::new();
        let mut overlaps: Vec<f64> = Vec::new();
        for _ in 0..epochs {
            let t0 = std::time::Instant::now();
            let run = pipeline.run_epoch(mode, |_, fleet, staged| {
                fleet.execute(staged, &mut model, &mut opt).loss
            });
            epoch_s.push(t0.elapsed().as_secs_f64());
            overlaps.push(run.overlap_factor());
            losses.extend(run.results);
        }
        (losses, epoch_s, overlaps)
    };
    let (serial_losses, serial_epoch_s, _) = sweep(ScheduleMode::Sequential);
    let (piped_losses, piped_epoch_s, overlaps) = sweep(ScheduleMode::Parallel);

    assert_eq!(
        serial_losses, piped_losses,
        "epoch pipelining changed numerics (must be bit-identical)"
    );

    let median = |xs: &[f64]| {
        let mut s = xs.to_vec();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    };
    let best_of = |xs: &[f64]| xs.iter().cloned().fold(0.0, f64::max);
    let mut best_overlap = best_of(&overlaps);
    // A single sweep's overlap is timing-dependent — on a loaded runner
    // the prepare worker can be scheduled only into the gaps between
    // execute spans. Retry a few times and keep the best, the same
    // pattern the sched overlap tests use; numerics stay asserted on
    // every attempt.
    for _ in 0..3 {
        if best_overlap > 1.0 {
            break;
        }
        let (retry_losses, _, retry_overlaps) = sweep(ScheduleMode::Parallel);
        assert_eq!(serial_losses, retry_losses, "retry changed numerics");
        best_overlap = best_overlap.max(best_of(&retry_overlaps));
    }
    let mut t = Table::new(
        &format!("epoch schedule sweep ({n_designs} Table-1 designs, {epochs} epochs)"),
        &["schedule", "median epoch ms", "speedup", "overlap (best)"],
    );
    t.row(&[
        "serial".to_string(),
        format!("{:.1}", median(&serial_epoch_s) * 1e3),
        "1.00x".to_string(),
        "1.00".to_string(),
    ]);
    t.row(&[
        "pipelined".to_string(),
        format!("{:.1}", median(&piped_epoch_s) * 1e3),
        fmt_speedup(median(&serial_epoch_s), median(&piped_epoch_s)),
        format!("{best_overlap:.2}"),
    ]);
    t.print();
    if num_threads() >= 2 {
        assert!(
            best_overlap > 1.0,
            "pipelined schedule must overlap prepare with execute on ≥2 cores \
             (best overlap {best_overlap})"
        );
    }
    println!(
        "epoch pipeline: losses bit-identical to the serial schedule (asserted); \
         overlap factor {best_overlap:.2} = prepare/execute busy time over makespan"
    );
    Json::obj()
        .set("designs", n_designs)
        .set("epochs", epochs)
        .set("fleet_spec", spec.describe())
        .set("requested_parts_per_graph", spec.parts().unwrap_or(1))
        .set("effective_subgraphs", effective_subgraphs)
        .set("serial_median_epoch_s", median(&serial_epoch_s))
        .set("pipelined_median_epoch_s", median(&piped_epoch_s))
        .set("best_overlap", best_overlap)
        .set("losses_bit_identical", true)
}
