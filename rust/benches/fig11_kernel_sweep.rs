//! E4 — regenerates paper **Fig. 11**: DR-SpMM forward/backward runtime
//! speedup under varying K against cuSPARSE and GNNA, across the three
//! representative designs (all graphs), embedding dims 64 and 128.
//!
//! Expected shape (paper §4.2): consistent acceleration while K < 32;
//! largest wins on `pins` (tall-thin adjacency), smallest on `near`
//! (square, dense); speedup decays toward K = dim; backward ≥ forward.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale, embedding, table1_graphs};
use dr_circuitgnn::bench::{measure, Table};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::sparse::{
    dr_spmm, dr_spmm_bwd, drelu, spmm_csr, spmm_csr_bwd, spmm_gnna, spmm_gnna_bwd, DegreeBuckets,
    GnnaConfig,
};
use dr_circuitgnn::util::math::geomean;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps();
    let ks = [2usize, 4, 8, 16, 32, 64];
    let gnna_cfg = GnnaConfig::default();
    println!("Fig. 11 — kernel sweep (scale {scale}, reps {reps})");

    for dim in [64usize, 128] {
        // Collect per-edge-type speedups for the summary.
        let mut sum_fwd_csr: Vec<f64> = Vec::new();
        let mut sum_bwd_csr: Vec<f64> = Vec::new();
        let mut sum_fwd_gnna: Vec<f64> = Vec::new();
        let mut sum_bwd_gnna: Vec<f64> = Vec::new();
        for (name, graphs) in table1_graphs(scale) {
            for g in &graphs {
                let mut t = Table::new(
                    &format!("{name} graph {} dim {dim}", g.id),
                    &[
                        "edge", "K", "DR fwd ms", "DR bwd ms", "fwd/cuSP", "bwd/cuSP",
                        "fwd/GNNA", "bwd/GNNA",
                    ],
                );
                for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
                    let adj = g.adj(edge);
                    let csc = adj.to_csc();
                    let buckets = DegreeBuckets::build(adj);
                    let x = embedding(adj.cols, dim, 7 + g.id as u64);
                    let dy = embedding(adj.rows, dim, 17 + g.id as u64);
                    let t_csr_f =
                        measure(1, reps, || std::hint::black_box(spmm_csr(adj, &x))).median;
                    let t_csr_b =
                        measure(1, reps, || std::hint::black_box(spmm_csr_bwd(&csc, &dy))).median;
                    let t_gnna_f = measure(1, reps, || {
                        std::hint::black_box(spmm_gnna(adj, &x, &gnna_cfg))
                    })
                    .median;
                    let t_gnna_b = measure(1, reps, || {
                        std::hint::black_box(spmm_gnna_bwd(&csc, &dy, &gnna_cfg))
                    })
                    .median;
                    for &k in ks.iter().filter(|&&k| k <= dim) {
                        let compressed = drelu(&x, k);
                        let t_f = measure(1, reps, || {
                            std::hint::black_box(dr_spmm(adj, &compressed, &buckets))
                        })
                        .median;
                        let t_b = measure(1, reps, || {
                            std::hint::black_box(dr_spmm_bwd(&csc, &dy, &compressed))
                        })
                        .median;
                        t.row(&[
                            edge.name().to_string(),
                            k.to_string(),
                            format!("{:.3}", t_f * 1e3),
                            format!("{:.3}", t_b * 1e3),
                            format!("{:.2}x", t_csr_f / t_f),
                            format!("{:.2}x", t_csr_b / t_b),
                            format!("{:.2}x", t_gnna_f / t_f),
                            format!("{:.2}x", t_gnna_b / t_b),
                        ]);
                        if k <= 8 {
                            sum_fwd_csr.push(t_csr_f / t_f);
                            sum_bwd_csr.push(t_csr_b / t_b);
                            sum_fwd_gnna.push(t_gnna_f / t_f);
                            sum_bwd_gnna.push(t_gnna_b / t_b);
                        }
                    }
                }
                t.print();
            }
        }
        println!(
            "dim {dim} summary (K ≤ 8, geomean): vs cuSPARSE fwd {:.2}x bwd {:.2}x | vs GNNA fwd {:.2}x bwd {:.2}x",
            geomean(&sum_fwd_csr),
            geomean(&sum_bwd_csr),
            geomean(&sum_fwd_gnna),
            geomean(&sum_bwd_gnna),
        );
        println!("paper: dim 64 best 3.21x/3.51x vs cuSPARSE, 2.75x/4.09x vs GNNA (fwd/bwd)\n");
    }
}
