//! E4 — regenerates paper **Fig. 11**: DR-SpMM forward/backward runtime
//! speedup under varying K against cuSPARSE and GNNA, across the three
//! representative designs (all graphs), embedding dims 64 and 128.
//!
//! All kernels run through the engine's plan/execute API: one engine per
//! (graph, kernel) pair plans the three edge types once, and the timed
//! regions are pure `aggregate_with`/`aggregate_backward_raw` calls (the
//! compressed DR backward is timed in its native representation, like the
//! paper's Alg. 2 output).
//!
//! Expected shape (paper §4.2): consistent acceleration while K < 32;
//! largest wins on `pins` (tall-thin adjacency), smallest on `near`
//! (square, dense); speedup decays toward K = dim; backward ≥ forward.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale, embedding, table1_graphs};
use dr_circuitgnn::bench::{measure, write_bench_json, Json, Table};
use dr_circuitgnn::engine::{AggCache, EngineBuilder};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::util::math::geomean;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps();
    let ks = [2usize, 4, 8, 16, 32, 64];
    println!("Fig. 11 — kernel sweep (scale {scale}, reps {reps})");

    // One JSON row per (design, graph, dim, edge, kernel[, K]) measurement.
    let mut json_rows: Vec<Json> = Vec::new();
    let row_base = |design: &str, gid: usize, dim: usize, edge: EdgeType, kernel: &str| {
        Json::obj()
            .set("design", design)
            .set("graph", gid)
            .set("dim", dim)
            .set("edge", edge.name())
            .set("kernel", kernel)
    };

    for dim in [64usize, 128] {
        // Collect per-edge-type speedups for the summary.
        let mut sum_fwd_csr: Vec<f64> = Vec::new();
        let mut sum_bwd_csr: Vec<f64> = Vec::new();
        let mut sum_fwd_gnna: Vec<f64> = Vec::new();
        let mut sum_bwd_gnna: Vec<f64> = Vec::new();
        for (name, graphs) in table1_graphs(scale) {
            for g in &graphs {
                let csr = EngineBuilder::csr().build(g);
                let gnna = EngineBuilder::gnna(GnnaConfig::default()).build(g);
                let ell = EngineBuilder::default().kernel("ell").build(g);
                let bcsr = EngineBuilder::default().kernel("bcsr").build(g);
                // One DR engine per K, planned once per graph (not per edge).
                let dr_engines: Vec<_> = ks
                    .iter()
                    .filter(|&&k| k <= dim)
                    .map(|&k| (k, EngineBuilder::dr(k, k).build(g)))
                    .collect();
                let mut t = Table::new(
                    &format!("{name} graph {} dim {dim}", g.id),
                    &[
                        "edge", "K", "DR fwd ms", "DR bwd ms", "fwd/cuSP", "bwd/cuSP",
                        "fwd/GNNA", "bwd/GNNA",
                    ],
                );
                // Dense-layout backends are K-independent: one row per edge.
                let mut tb = Table::new(
                    &format!("{name} graph {} dim {dim} — dense-layout baselines", g.id),
                    &[
                        "edge", "ELL fwd ms", "ELL bwd ms", "BCSR fwd ms", "BCSR bwd ms",
                        "ELL fwd/cuSP", "BCSR fwd/cuSP",
                    ],
                );
                for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
                    let adj = g.adj(edge);
                    let x = embedding(adj.cols, dim, 7 + g.id as u64);
                    let dy = embedding(adj.rows, dim, 17 + g.id as u64);
                    let t_csr_f = measure(1, reps, || {
                        std::hint::black_box(csr.aggregate_with(edge, &x, None))
                    })
                    .median;
                    let t_csr_b = measure(1, reps, || {
                        std::hint::black_box(csr.aggregate_backward_raw(
                            edge,
                            &dy,
                            &AggCache::None,
                        ))
                    })
                    .median;
                    let t_gnna_f = measure(1, reps, || {
                        std::hint::black_box(gnna.aggregate_with(edge, &x, None))
                    })
                    .median;
                    let t_gnna_b = measure(1, reps, || {
                        std::hint::black_box(gnna.aggregate_backward_raw(
                            edge,
                            &dy,
                            &AggCache::None,
                        ))
                    })
                    .median;
                    let t_ell_f = measure(1, reps, || {
                        std::hint::black_box(ell.aggregate_with(edge, &x, None))
                    })
                    .median;
                    let t_ell_b = measure(1, reps, || {
                        std::hint::black_box(ell.aggregate_backward_raw(edge, &dy, &AggCache::None))
                    })
                    .median;
                    let t_bcsr_f = measure(1, reps, || {
                        std::hint::black_box(bcsr.aggregate_with(edge, &x, None))
                    })
                    .median;
                    let t_bcsr_b = measure(1, reps, || {
                        std::hint::black_box(bcsr.aggregate_backward_raw(
                            edge,
                            &dy,
                            &AggCache::None,
                        ))
                    })
                    .median;
                    tb.row(&[
                        edge.name().to_string(),
                        format!("{:.3}", t_ell_f * 1e3),
                        format!("{:.3}", t_ell_b * 1e3),
                        format!("{:.3}", t_bcsr_f * 1e3),
                        format!("{:.3}", t_bcsr_b * 1e3),
                        format!("{:.2}x", t_csr_f / t_ell_f),
                        format!("{:.2}x", t_csr_f / t_bcsr_f),
                    ]);
                    for (kernel, tf, tbwd) in [
                        ("csr", t_csr_f, t_csr_b),
                        ("gnna", t_gnna_f, t_gnna_b),
                        ("ell", t_ell_f, t_ell_b),
                        ("bcsr", t_bcsr_f, t_bcsr_b),
                    ] {
                        json_rows.push(
                            row_base(&name, g.id, dim, edge, kernel)
                                .set("fwd_ms", tf * 1e3)
                                .set("bwd_ms", tbwd * 1e3),
                        );
                    }
                    for (k, dr) in &dr_engines {
                        let k = *k;
                        // D-ReLU runs once outside the timed region, like
                        // the activation stage of the training pipeline.
                        let prep = dr.sparsify(&x, edge.endpoints().0).expect("DR sparsifies");
                        let cache = AggCache::Cbsr(prep.clone());
                        let t_f = measure(1, reps, || {
                            std::hint::black_box(dr.aggregate_with(edge, &x, Some(&prep)))
                        })
                        .median;
                        let t_b = measure(1, reps, || {
                            std::hint::black_box(dr.aggregate_backward_raw(edge, &dy, &cache))
                        })
                        .median;
                        t.row(&[
                            edge.name().to_string(),
                            k.to_string(),
                            format!("{:.3}", t_f * 1e3),
                            format!("{:.3}", t_b * 1e3),
                            format!("{:.2}x", t_csr_f / t_f),
                            format!("{:.2}x", t_csr_b / t_b),
                            format!("{:.2}x", t_gnna_f / t_f),
                            format!("{:.2}x", t_gnna_b / t_b),
                        ]);
                        json_rows.push(
                            row_base(&name, g.id, dim, edge, "dr")
                                .set("k", k)
                                .set("fwd_ms", t_f * 1e3)
                                .set("bwd_ms", t_b * 1e3)
                                .set("fwd_speedup_vs_csr", t_csr_f / t_f)
                                .set("bwd_speedup_vs_csr", t_csr_b / t_b),
                        );
                        if k <= 8 {
                            sum_fwd_csr.push(t_csr_f / t_f);
                            sum_bwd_csr.push(t_csr_b / t_b);
                            sum_fwd_gnna.push(t_gnna_f / t_f);
                            sum_bwd_gnna.push(t_gnna_b / t_b);
                        }
                    }
                }
                t.print();
                tb.print();
            }
        }
        println!(
            "dim {dim} summary (K ≤ 8, geomean): vs cuSPARSE fwd {:.2}x bwd {:.2}x | vs GNNA fwd {:.2}x bwd {:.2}x",
            geomean(&sum_fwd_csr),
            geomean(&sum_bwd_csr),
            geomean(&sum_fwd_gnna),
            geomean(&sum_bwd_gnna),
        );
        println!("paper: dim 64 best 3.21x/3.51x vs cuSPARSE, 2.75x/4.09x vs GNNA (fwd/bwd)\n");
    }

    let json = Json::obj()
        .set("scale", scale)
        .set("reps", reps)
        .set("ks", ks.to_vec())
        .set("kernels", vec!["csr", "gnna", "ell", "bcsr", "dr"])
        .set("rows", Json::arr(json_rows));
    write_bench_json("fig11_kernel_sweep", &json);
}
