//! E1 — regenerates paper **Table 1**: statistics of the three
//! representative circuit designs (9282-zero, 2216-RISCY, 7598-zero).
//!
//! At DRCG_BENCH_SCALE=1.0 the node/edge counts match the published table
//! exactly (by construction of the generator targets); the default bench
//! scale shrinks all counts proportionally.

use dr_circuitgnn::bench::workloads::{bench_scale, table1_graphs};
use dr_circuitgnn::bench::Table;

fn main() {
    let scale = bench_scale();
    let mut t = Table::new(
        &format!("Table 1 — circuit design statistics (scale {scale})"),
        &[
            "design", "graph", "nodes-net", "nodes-cell", "edges-pinned", "edges-near",
            "edges-pins", "total nodes", "total edges",
        ],
    );
    for (name, graphs) in table1_graphs(scale) {
        for g in &graphs {
            g.validate().expect("generated graph invalid");
            let s = g.stats_row();
            assert_eq!(s.edges_pins, s.edges_pinned, "pins and pinned must mirror");
            t.row(&[
                name.clone(),
                s.id.to_string(),
                s.nodes_net.to_string(),
                s.nodes_cell.to_string(),
                s.edges_pinned.to_string(),
                s.edges_near.to_string(),
                s.edges_pins.to_string(),
                s.total_nodes().to_string(),
                s.total_edges().to_string(),
            ]);
        }
    }
    t.print();
    println!(
        "paper @ scale 1.0: 9282-zero g0 = (4628, 7767, 10013, 338050, 10013, 12395, 358076)"
    );
}
