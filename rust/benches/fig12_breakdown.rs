//! E8 — regenerates paper **Fig. 12**: breakdown of the optimization
//! benefits on 9 randomly-selected CircuitNet graphs. Bars: *DR-ReLU
//! savings* (kernel-only: DR engine, sequential) and *parallel savings*
//! (DR engine + parallel schedule) vs the cuSPARSE sequential baseline.
//!
//! Paper: kernel optimization averages 19.3% e2e time reduction (9–39%
//! depending on topology); the parallel scheme averages a further 49.6%.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale};
use dr_circuitgnn::bench::Table;
use dr_circuitgnn::datagen::generate_design;
use dr_circuitgnn::nn::MessageEngine;
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::util::math::mean;
use dr_circuitgnn::util::rng::Rng;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps().max(3);
    let dim = 64usize;
    println!("Fig. 12 — optimization breakdown on 9 random graphs (scale {scale})");

    // 9 random CircuitNet-like graphs.
    let mut rng = Rng::new(99);
    let mut graphs = Vec::new();
    while graphs.len() < 9 {
        let spec = dr_circuitgnn::datagen::designs::random_design_spec(
            &format!("rand-{}", graphs.len()),
            scale,
            &mut rng,
        );
        for g in generate_design(&spec) {
            if graphs.len() < 9 {
                graphs.push(g);
            }
        }
    }

    let median = |g: &dr_circuitgnn::graph::HeteroGraph,
                  engine: &MessageEngine,
                  mode: ScheduleMode| {
        let mut s: Vec<f64> =
            (0..reps).map(|r| run_e2e_step(g, dim, engine, mode, 7 + r as u64).total).collect();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    };

    let mut t = Table::new(
        "e2e time reduction vs cuSPARSE sequential",
        &["graph", "baseline ms", "DR-ReLU saving", "parallel saving", "combined"],
    );
    let mut kernel_savings = Vec::new();
    let mut parallel_savings = Vec::new();
    for (i, g) in graphs.iter().enumerate() {
        let base = median(g, &MessageEngine::Csr, ScheduleMode::Sequential);
        let kernel_only = median(g, &MessageEngine::dr(8, 8), ScheduleMode::Sequential);
        let combined = median(g, &MessageEngine::dr(8, 8), ScheduleMode::Parallel);
        let k_sav = 1.0 - kernel_only / base;
        let p_sav = (kernel_only - combined) / base; // additional saving from parallelism
        kernel_savings.push(k_sav);
        parallel_savings.push(p_sav);
        t.row(&[
            format!("graph{i}"),
            format!("{:.1}", base * 1e3),
            format!("{:.1}%", k_sav * 100.0),
            format!("{:.1}%", p_sav * 100.0),
            format!("{:.1}%", (1.0 - combined / base) * 100.0),
        ]);
    }
    t.row(&[
        "Average".into(),
        "-".into(),
        format!("{:.1}%", mean(&kernel_savings) * 100.0),
        format!("{:.1}%", mean(&parallel_savings) * 100.0),
        format!(
            "{:.1}%",
            (mean(&kernel_savings) + mean(&parallel_savings)) * 100.0
        ),
    ]);
    t.print();
    println!("paper: DR-ReLU avg 19.3% (range 9–39%), parallel avg 49.6%");
}
