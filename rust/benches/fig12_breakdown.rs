//! E8 — regenerates paper **Fig. 12**: breakdown of the optimization
//! benefits on 9 randomly-selected CircuitNet graphs. Bars: *DR-ReLU
//! savings* (kernel-only: DR engine, sequential) and *parallel savings*
//! (DR engine + parallel schedule) vs the cuSPARSE sequential baseline.
//!
//! Also demonstrates the engine's **plan caching**: building one engine per
//! graph constructs exactly 3 plans (CSC + buckets) per graph, and running
//! many training-style steps through those engines constructs zero more —
//! asserted via the engine's global plan counters. (The e2e step rig below
//! re-plans per step *by design*: its lane init phase is the paper's
//! per-step "data loading / memory allocation" cost.)
//!
//! Paper: kernel optimization averages 19.3% e2e time reduction (9–39%
//! depending on topology); the parallel scheme averages a further 49.6%.

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale};
use dr_circuitgnn::bench::{write_bench_json, Json, Table};
use dr_circuitgnn::datagen::generate_design;
use dr_circuitgnn::engine::{plan_counters, Engine, EngineBuilder};
use dr_circuitgnn::fleet::PlanCache;
use dr_circuitgnn::graph::{EdgeType, HeteroGraph};
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::util::math::mean;
use dr_circuitgnn::util::rng::Rng;
use std::time::Instant;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps().max(3);
    let dim = 64usize;
    println!("Fig. 12 — optimization breakdown on 9 random graphs (scale {scale})");

    // 9 random CircuitNet-like graphs.
    let mut rng = Rng::new(99);
    let mut graphs = Vec::new();
    while graphs.len() < 9 {
        let spec = dr_circuitgnn::datagen::designs::random_design_spec(
            &format!("rand-{}", graphs.len()),
            scale,
            &mut rng,
        );
        for g in generate_design(&spec) {
            if graphs.len() < 9 {
                graphs.push(g);
            }
        }
    }

    // --- Plan-caching demonstration (acceptance: CSC + bucket construction
    // happens once per graph per kernel, not per layer per step).
    let c0 = plan_counters();
    let engines: Vec<Engine> =
        graphs.iter().map(|g| EngineBuilder::dr(8, 8).build(g)).collect();
    let built = plan_counters().since(&c0);
    assert_eq!(built.plans, 3 * graphs.len(), "one plan per edge type per graph");
    assert_eq!(built.cscs, built.plans, "one CSC transpose per plan");
    assert_eq!(built.buckets, built.plans, "DR plans carry degree buckets");
    let steps = 20usize;
    let c1 = plan_counters();
    for (g, eng) in graphs.iter().zip(&engines) {
        let x_cell = dr_circuitgnn::tensor::Matrix::randn(g.n_cells, dim, 1.0, &mut rng);
        let x_net = dr_circuitgnn::tensor::Matrix::randn(g.n_nets, dim, 1.0, &mut rng);
        for _ in 0..steps {
            // One D-ReLU per node type per step, shared by the consumers —
            // then fwd+bwd over all three edge types, training-style.
            let prep_c = eng.sparsify(&x_cell, dr_circuitgnn::graph::NodeType::Cell);
            let prep_n = eng.sparsify(&x_net, dr_circuitgnn::graph::NodeType::Net);
            for (e, x, prep) in [
                (EdgeType::Near, &x_cell, prep_c.as_ref()),
                (EdgeType::Pins, &x_cell, prep_c.as_ref()),
                (EdgeType::Pinned, &x_net, prep_n.as_ref()),
            ] {
                let (h, cache) = eng.aggregate_with(e, x, prep);
                let _ = eng.aggregate_backward(e, &h, &cache);
            }
        }
    }
    let during_steps = plan_counters().since(&c1);
    assert_eq!(
        during_steps.plans, 0,
        "no plan construction during {steps} fwd+bwd steps per graph"
    );
    println!(
        "plan caching: {} plans ({} graphs × 3 edges) built once; {} built across {} steps/graph",
        built.plans,
        graphs.len(),
        during_steps.plans,
        steps
    );

    // --- Plan-store cold/warm sweep: the same 9 graphs through a
    // disk-backed cache twice. The cold pass builds and persists every
    // plan; the warm pass (a fresh cache over the same directory) loads
    // them all — zero Alg. 1 stage 1 plan builds, asserted against both
    // the cache's own stats and the engine's global plan counters.
    let store_dir = std::env::temp_dir().join(format!("drcg-fig12-store-{}", std::process::id()));
    std::fs::create_dir_all(&store_dir).expect("create plan-store dir");
    let (cold_secs, warm_secs) = {
        let cold_cache = PlanCache::backed_by(EngineBuilder::dr(8, 8), &store_dir)
            .expect("open plan store");
        let t0 = Instant::now();
        for g in &graphs {
            let _ = cold_cache.engine_for(g);
        }
        let cold_secs = t0.elapsed().as_secs_f64();
        let cold = cold_cache.stats();
        assert_eq!(cold.misses, graphs.len(), "cold pass builds every plan");
        assert_eq!(cold.disk_stores, graphs.len(), "cold pass persists every plan");
        assert_eq!(cold.disk_loads, 0);

        let warm_cache = PlanCache::backed_by(EngineBuilder::dr(8, 8), &store_dir)
            .expect("reopen plan store");
        let c2 = plan_counters();
        let t0 = Instant::now();
        for g in &graphs {
            let _ = warm_cache.engine_for(g);
        }
        let warm_secs = t0.elapsed().as_secs_f64();
        let warm = warm_cache.stats();
        assert_eq!(warm.disk_loads, graphs.len(), "warm pass loads every plan");
        assert_eq!(warm.misses, 0, "warm pass builds nothing cold");
        let rebuilt = plan_counters().since(&c2);
        assert_eq!(rebuilt.plans, 0, "warm loads register zero plan builds");
        (cold_secs, warm_secs)
    };
    std::fs::remove_dir_all(&store_dir).ok();
    println!(
        "plan store: cold pass {:.1}ms (build + persist), warm pass {:.1}ms (load), {:.2}x",
        cold_secs * 1e3,
        warm_secs * 1e3,
        cold_secs / warm_secs.max(1e-12)
    );

    let median = |g: &HeteroGraph, engine: &EngineBuilder, mode: ScheduleMode| {
        let mut s: Vec<f64> =
            (0..reps).map(|r| run_e2e_step(g, dim, engine, mode, 7 + r as u64).total).collect();
        s.sort_by(|a, b| a.partial_cmp(b).unwrap());
        s[s.len() / 2]
    };

    let mut t = Table::new(
        "e2e time reduction vs cuSPARSE sequential",
        &["graph", "baseline ms", "DR-ReLU saving", "parallel saving", "combined"],
    );
    let mut kernel_savings = Vec::new();
    let mut parallel_savings = Vec::new();
    let mut json_graphs = Vec::new();
    let csr = EngineBuilder::csr();
    let dr = EngineBuilder::dr(8, 8);
    for (i, g) in graphs.iter().enumerate() {
        let base = median(g, &csr, ScheduleMode::Sequential);
        let kernel_only = median(g, &dr, ScheduleMode::Sequential);
        let combined = median(g, &dr, ScheduleMode::Parallel);
        let k_sav = 1.0 - kernel_only / base;
        let p_sav = (kernel_only - combined) / base; // additional saving from parallelism
        kernel_savings.push(k_sav);
        parallel_savings.push(p_sav);
        json_graphs.push(
            Json::obj()
                .set("graph", format!("graph{i}"))
                .set("baseline_s", base)
                .set("dr_sequential_s", kernel_only)
                .set("dr_parallel_s", combined)
                .set("kernel_saving", k_sav)
                .set("parallel_saving", p_sav),
        );
        t.row(&[
            format!("graph{i}"),
            format!("{:.1}", base * 1e3),
            format!("{:.1}%", k_sav * 100.0),
            format!("{:.1}%", p_sav * 100.0),
            format!("{:.1}%", (1.0 - combined / base) * 100.0),
        ]);
    }
    t.row(&[
        "Average".into(),
        "-".into(),
        format!("{:.1}%", mean(&kernel_savings) * 100.0),
        format!("{:.1}%", mean(&parallel_savings) * 100.0),
        format!(
            "{:.1}%",
            (mean(&kernel_savings) + mean(&parallel_savings)) * 100.0
        ),
    ]);
    t.print();
    println!("paper: DR-ReLU avg 19.3% (range 9–39%), parallel avg 49.6%");

    let json = Json::obj()
        .set("bench", "fig12_breakdown")
        .set("scale", scale)
        .set("reps", reps)
        .set("dim", dim)
        .set(
            "plan_cache",
            Json::obj()
                .set("plans_built_once", built.plans)
                .set("plans_built_during_steps", during_steps.plans)
                .set("steps_per_graph", steps),
        )
        .set(
            "plan_store",
            Json::obj()
                .set("cold_pass_s", cold_secs)
                .set("warm_pass_s", warm_secs)
                .set("speedup", cold_secs / warm_secs.max(1e-12)),
        )
        .set("graphs", Json::arr(json_graphs))
        .set("avg_kernel_saving", mean(&kernel_savings))
        .set("avg_parallel_saving", mean(&parallel_savings));
    write_bench_json("fig12_breakdown", &json);
}
