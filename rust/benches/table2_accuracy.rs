//! E5 — regenerates paper **Table 2**: congestion-prediction quality on
//! Mini-CircuitNet — GCN / SAGE / GAT homogeneous baselines vs
//! DR-CircuitGNN (Pearson / Spearman / Kendall / MAE / RMSE).
//!
//! Expected shape (paper): DR-CircuitGNN wins all three rank-correlation
//! metrics (0.442 / 0.511 / 0.384 vs ≈0.347 / 0.494 / 0.373) while MAE and
//! RMSE worsen slightly (0.043 / 0.098 vs 0.027 / 0.033) — the D-ReLU
//! sparsification shifts absolute values but preserves ranking.
//!
//! Env knobs: DRCG_BENCH_DESIGNS (default 12), DRCG_BENCH_EPOCHS (default
//! 12), DRCG_BENCH_SCALE (default 0.25 → ≈2k nodes/graph). Paper-scale:
//! 120 designs, 50 epochs, scale 1.0 — hours on CPU.

use dr_circuitgnn::bench::Table;
use dr_circuitgnn::datagen::mini_circuitnet;
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::nn::HomoKind;
use dr_circuitgnn::train::{TrainConfig, Trainer};

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

fn main() {
    let scale = std::env::var("DRCG_BENCH_SCALE")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(0.15)
        .min(1.0);
    let n_designs = env_usize("DRCG_BENCH_DESIGNS", 9);
    let epochs = env_usize("DRCG_BENCH_EPOCHS", 8);
    println!(
        "Table 2 — Mini-CircuitNet congestion prediction ({n_designs} designs, {epochs} epochs, scale {scale})"
    );
    let (train, test) = mini_circuitnet(n_designs, scale, 42);

    let mut t = Table::new(
        "congestion prediction",
        &["model", "Pearson", "Spear.", "Ken.", "MAE", "RMSE", "params", "train s"],
    );

    let homo_cfg = TrainConfig {
        epochs,
        lr: 1e-3,
        weight_decay: 2e-4,
        hidden: 64,
        seed: 1,
        parallel: false,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    };
    let mut homo_scores = Vec::new();
    for kind in [HomoKind::Gcn, HomoKind::Sage, HomoKind::Gat] {
        let (_m, r) = Trainer::train_homo(kind, &train, &test, &homo_cfg);
        homo_scores.push(r.test_scores);
        t.row(&[
            kind.name().to_string(),
            format!("{:.3}", r.test_scores.pearson),
            format!("{:.3}", r.test_scores.spearman),
            format!("{:.3}", r.test_scores.kendall),
            format!("{:.3}", r.test_scores.mae),
            format!("{:.3}", r.test_scores.rmse),
            r.params.to_string(),
            format!("{:.1}", r.train_seconds),
        ]);
    }

    // Paper lr is 2e-4 over 50 epochs; in the shortened default regime
    // (8 epochs) that undertrains the larger DR model relative to the
    // baselines' 1e-3 — scale the lr so optimization progress is
    // comparable. At DRCG_BENCH_EPOCHS ≥ 40 this reduces to the paper's.
    let dr_lr = if epochs >= 40 { 2e-4 } else { 1e-3 };
    let dr_cfg = TrainConfig {
        epochs,
        lr: dr_lr,
        weight_decay: 1e-5,
        hidden: 64,
        seed: 1,
        parallel: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1) > 1,
        epoch_pipeline: false,
        log_every: 0,
        ..TrainConfig::dr_default()
    };
    let (_m, r) = Trainer::train_dr(&train, &test, &EngineBuilder::dr(8, 8), &dr_cfg);
    t.row(&[
        "DR-CircuitGNN (ours)".to_string(),
        format!("{:.3}", r.test_scores.pearson),
        format!("{:.3}", r.test_scores.spearman),
        format!("{:.3}", r.test_scores.kendall),
        format!("{:.3}", r.test_scores.mae),
        format!("{:.3}", r.test_scores.rmse),
        r.params.to_string(),
        format!("{:.1}", r.train_seconds),
    ]);
    t.print();
    println!(
        "paper: GCN/SAGE/GAT ≈ (0.347, 0.494, 0.373, 0.027, 0.033); \
         DR-CircuitGNN (0.442, 0.511, 0.384, 0.043, 0.098)"
    );
    let best_homo_spear =
        homo_scores.iter().map(|s| s.spearman).fold(f64::NEG_INFINITY, f64::max);
    println!(
        "shape check — DR Spearman {:.3} vs best homo {:.3}: {}",
        r.test_scores.spearman,
        best_homo_spear,
        if r.test_scores.spearman >= best_homo_spear - 0.05 { "OK" } else { "DIVERGES" }
    );
}
