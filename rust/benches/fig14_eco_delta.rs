//! E12 — **Fig. 14 (repo extension)**: incremental ECO delta updates.
//!
//! An ECO (engineering change order) edits a small fraction of an
//! already-planned design. The from-scratch response re-partitions the
//! patched design and cold-plans every partition; the incremental response
//! ([`dr_circuitgnn::fleet::apply_eco`]) routes the delta through the
//! partition maps, keeps untouched partitions verbatim, *repairs* the
//! cached plans of patched partitions (only dirty rows/columns are
//! rebuilt), and re-cuts only the partitions whose net set changed. This
//! bench sweeps the churn rate and measures both responses on the largest
//! Table-1 design, asserting along the way that
//!
//! * the incremental path cold-plans **only** the restaged partitions
//!   (global plan counters: `plans == 3 × restaged`, `repairs` matches the
//!   per-partition repair stats — the "only touched structures" proof the
//!   CI smoke greps for), and
//! * training on the incrementally updated fleet is **bit-identical** to
//!   training on the from-scratch rebuild (matched golden-trace accuracy).
//!
//! Run: `cargo bench --bench fig14_eco_delta` (env `DRCG_BENCH_SCALE`,
//! `DRCG_BENCH_REPS` as usual).

use dr_circuitgnn::bench::workloads::{bench_reps, bench_scale};
use dr_circuitgnn::bench::{fmt_speedup, write_bench_json, Json, Table};
use dr_circuitgnn::datagen::{generate_design, generate_eco, table1_designs, EcoSpec};
use dr_circuitgnn::engine::{plan_counters, EngineBuilder};
use dr_circuitgnn::fleet::{apply_eco, Fleet, PlanCache};
use dr_circuitgnn::graph::{apply_delta, partition_with_map, HeteroGraph};
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::util::rng::Rng;

const PARTS: usize = 8;
const TRAIN_STEPS: usize = 3;

fn main() {
    let scale = bench_scale();
    let reps = bench_reps().max(3);
    println!("Fig. 14 — incremental ECO delta updates (scale {scale}, {PARTS} partitions)");

    // The largest single graph of the largest Table-1 design, partitioned
    // like a fleet run would partition it.
    let spec = table1_designs(scale).into_iter().last().expect("table1 designs");
    let parent = generate_design(&spec)
        .into_iter()
        .max_by_key(|g| g.n_cells)
        .expect("design has graphs");
    let subs = partition_with_map(&parent, PARTS);
    println!(
        "design {} ({} cells, {} nets) → {} partitions",
        spec.name,
        parent.n_cells,
        parent.n_nets,
        subs.len()
    );

    let builder = || EngineBuilder::dr(8, 8);
    let mut rng = Rng::new(42);
    let model0 = DrCircuitGnn::new(parent.x_cell.cols, parent.x_net.cols, 32, &mut rng);

    let mut t = Table::new(
        &format!("ECO replan: incremental delta vs from-scratch ({})", spec.name),
        &["churn", "edge ops", "untouched/patched/restaged", "full ms", "delta ms", "speedup"],
    );
    let mut rows = Vec::new();
    for (i, churn) in [0.002f64, 0.01, 0.05].into_iter().enumerate() {
        let patch = generate_eco(&parent, &EcoSpec::new(churn, 42 + i as u64));

        // From-scratch response: apply, re-partition, cold-plan everything.
        let mut full_samples = Vec::with_capacity(reps);
        let mut full_plans = 0usize;
        for _ in 0..reps {
            let cache = PlanCache::new(builder());
            let c0 = plan_counters();
            let t0 = std::time::Instant::now();
            let patched = apply_delta(&parent, &patch).expect("generated ECOs apply");
            for (sub, _) in &partition_with_map(&patched, PARTS) {
                let _ = cache.engine_for(sub);
            }
            full_samples.push(t0.elapsed().as_secs_f64());
            full_plans = plan_counters().since(&c0).plans;
        }

        // Incremental response against a warm cache (the steady state:
        // the fleet was already planned before the ECO arrived).
        let mut delta_samples = Vec::with_capacity(reps);
        let mut report = None;
        let mut delta_plans = 0usize;
        let mut delta_repairs = 0usize;
        for _ in 0..reps {
            let cache = PlanCache::new(builder());
            for (sub, _) in &subs {
                let _ = cache.engine_for(sub);
            }
            let c0 = plan_counters();
            let t0 = std::time::Instant::now();
            let outcome = apply_eco(&parent, &subs, &patch, &cache).expect("routed ECO applies");
            delta_samples.push(t0.elapsed().as_secs_f64());
            let since = plan_counters().since(&c0);
            delta_plans = since.plans;
            delta_repairs = since.repairs;
            // The only-touched-structures proof: cold plans happen for
            // restaged partitions alone (3 edge types each); everything
            // else is a cache hit or an incremental repair.
            assert_eq!(
                since.plans,
                3 * outcome.report.restaged,
                "delta replan cold-planned an untouched partition: {}",
                outcome.report.describe()
            );
            assert_eq!(since.repairs, outcome.report.repair.plans_repaired);
            // Every repaired lookup resolves its 3 plans by pointer reuse
            // or incremental repair — never a cold rebuild (the kernel
            // selection is static here, so the rebuild tier can't trigger).
            // Patched partitions whose adjacency hash didn't change (pure
            // feature/reweight edits) are plain cache hits, not repairs.
            let rep = &outcome.report.repair;
            assert_eq!(
                rep.plans_reused + rep.plans_repaired,
                3 * outcome.report.cache.repairs,
                "{}",
                rep.describe()
            );
            assert_eq!(rep.plans_rebuilt, 0, "{}", rep.describe());
            report = Some(outcome);
        }
        let outcome = report.expect("at least one rep");
        let r = outcome.report;

        // Matched accuracy: training on the incrementally updated fleet is
        // bit-identical to training on the from-scratch rebuild.
        let delta_graphs: Vec<HeteroGraph> =
            outcome.subgraphs.iter().map(|s| s.graph.clone()).collect();
        let fresh_graphs: Vec<HeteroGraph> = {
            let patched = apply_delta(&parent, &patch).unwrap();
            partition_with_map(&patched, PARTS).into_iter().map(|(g, _)| g).collect()
        };
        let losses = |graphs: &[HeteroGraph]| -> Vec<f64> {
            let fleet = Fleet::builder(builder()).workers(2).build(graphs);
            let mut model = model0.clone();
            let mut opt = Adam::new(2e-4, 1e-5);
            (0..TRAIN_STEPS).map(|_| fleet.step(&mut model, &mut opt).loss).collect()
        };
        let delta_losses = losses(&delta_graphs);
        let fresh_losses = losses(&fresh_graphs);
        assert_eq!(
            delta_losses, fresh_losses,
            "incremental ECO update changed training numerics (churn {churn})"
        );

        let median = |xs: &mut Vec<f64>| {
            xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
            xs[xs.len() / 2]
        };
        let (mut fs, mut ds) = (full_samples, delta_samples);
        let (full_ms, delta_ms) = (median(&mut fs), median(&mut ds));
        t.row(&[
            format!("{:.1}%", churn * 100.0),
            patch.n_edge_ops().to_string(),
            format!("{}/{}/{}", r.untouched, r.patched, r.restaged),
            format!("{:.2}", full_ms * 1e3),
            format!("{:.2}", delta_ms * 1e3),
            fmt_speedup(full_ms, delta_ms),
        ]);
        rows.push(
            Json::obj()
                .set("churn", churn)
                .set("edge_ops", patch.n_edge_ops())
                .set("untouched", r.untouched)
                .set("patched", r.patched)
                .set("restaged", r.restaged)
                .set("evicted", r.evicted)
                .set("full_replan_s", full_ms)
                .set("delta_replan_s", delta_ms)
                .set("speedup", full_ms / delta_ms.max(1e-12))
                .set("cold_plans_full", full_plans)
                .set("cold_plans_delta", delta_plans)
                .set("plan_repairs", delta_repairs)
                .set("plans_reused", r.repair.plans_reused)
                .set("losses_bit_identical", true),
        );
    }
    t.print();
    println!(
        "delta replan cold-plans only restaged partitions (asserted: plans == \
         3×restaged, repairs match per-partition stats); training on the \
         incrementally updated fleet is bit-identical to from-scratch (asserted)"
    );

    let json = Json::obj()
        .set("bench", "fig14_eco_delta")
        .set("scale", scale)
        .set("reps", reps)
        .set("design", spec.name.clone())
        .set("partitions", subs.len())
        .set("requested_partitions", PARTS)
        .set("only_touched_replanned", true)
        .set("churn_sweep", Json::arr(rows));
    write_bench_json("fig14_eco_delta", &json);
}
