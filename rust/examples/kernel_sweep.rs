//! Kernel sweep: DR-SpMM forward/backward vs the cuSPARSE and GNNAdvisor
//! analogs across K values — a focused version of paper Fig. 11 on one
//! design (the full sweep lives in `cargo bench --bench fig11_kernel_sweep`).
//!
//! Everything dispatches through the engine: one `Engine` per kernel
//! family, plans (CSC / buckets / neighbor groups) built once per graph,
//! timed regions are pure plan-execution.
//!
//! Run: `cargo run --release --example kernel_sweep [-- --fast]`

use dr_circuitgnn::bench::{measure, Table};
use dr_circuitgnn::datagen::{generate_design, table1_design, DesignSize};
use dr_circuitgnn::engine::{AggCache, EngineBuilder};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::rng::Rng;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let scale = if fast { 0.1 } else { 0.5 };
    let reps = if fast { 3 } else { 7 };
    let dim = 64;

    let spec = table1_design(DesignSize::Medium, scale);
    let graphs = generate_design(&spec);
    let g = &graphs[0];
    println!(
        "design {} graph 0 at scale {scale}: {} cells / {} nets",
        spec.name, g.n_cells, g.n_nets
    );

    let csr = EngineBuilder::csr().build(g);
    let gnna = EngineBuilder::gnna(GnnaConfig::default()).build(g);
    // One DR engine per K, planned once per graph (not per edge).
    let dr_engines: Vec<_> = [2usize, 4, 8, 16, 32, 64]
        .into_iter()
        .map(|k| (k, EngineBuilder::dr(k, k).build(g)))
        .collect();
    let mut rng = Rng::new(11);
    for edge in [EdgeType::Near, EdgeType::Pins, EdgeType::Pinned] {
        let adj = g.adj(edge);
        let x = Matrix::randn(adj.cols, dim, 1.0, &mut rng);
        let dy = Matrix::randn(adj.rows, dim, 1.0, &mut rng);

        let t_csr_f = measure(1, reps, || {
            std::hint::black_box(csr.aggregate_with(edge, &x, None))
        })
        .median;
        let t_csr_b = measure(1, reps, || {
            std::hint::black_box(csr.aggregate_backward_raw(edge, &dy, &AggCache::None))
        })
        .median;
        let t_gnna_f = measure(1, reps, || {
            std::hint::black_box(gnna.aggregate_with(edge, &x, None))
        })
        .median;
        let t_gnna_b = measure(1, reps, || {
            std::hint::black_box(gnna.aggregate_backward_raw(edge, &dy, &AggCache::None))
        })
        .median;

        let mut table = Table::new(
            &format!("{} ({}×{}, {} nnz, dim {dim})", edge.name(), adj.rows, adj.cols, adj.nnz()),
            &[
                "K",
                "fwd ms",
                "bwd ms",
                "fwd vs cuSPARSE",
                "bwd vs cuSPARSE",
                "fwd vs GNNA",
                "bwd vs GNNA",
            ],
        );
        for (k, dr) in &dr_engines {
            let k = *k;
            let prep = dr.sparsify(&x, edge.endpoints().0).expect("DR sparsifies its source");
            let cache = AggCache::Cbsr(prep.clone());
            let t_f = measure(1, reps, || {
                std::hint::black_box(dr.aggregate_with(edge, &x, Some(&prep)))
            })
            .median;
            let t_b = measure(1, reps, || {
                std::hint::black_box(dr.aggregate_backward_raw(edge, &dy, &cache))
            })
            .median;
            table.row(&[
                k.to_string(),
                format!("{:.2}", t_f * 1e3),
                format!("{:.2}", t_b * 1e3),
                format!("{:.2}x", t_csr_f / t_f),
                format!("{:.2}x", t_csr_b / t_b),
                format!("{:.2}x", t_gnna_f / t_f),
                format!("{:.2}x", t_gnna_b / t_b),
            ]);
        }
        table.print();
    }
}
