//! End-to-end three-layer validation (experiment E10, see docs/ENGINE.md).
//!
//! Trains the DR-CircuitGNN congestion model **through the AOT path**:
//! the fused forward+backward train step was authored in JAX (L2), its
//! aggregations are the Pallas DR-SpMM kernels (L1), and this rust driver
//! (L3) loads the lowered HLO via PJRT, feeds padded circuit graphs,
//! applies Adam on the returned gradients and logs the loss curve —
//! python never runs here.
//!
//! Run: `make artifacts && cargo run --release --example congestion_training -- --steps 200`

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::nn::{Adam, Param};
use dr_circuitgnn::runtime::{pad_graph, pad_graph_strict, ArtifactRegistry, Bucket, Runtime};
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::train::metrics::EvalScores;
use dr_circuitgnn::util::rng::Rng;

/// The 19 *live* parameter tensors of the model, in the canonical order of
/// python/compile/model.py::LIVE_PARAM_KEYS (conv2.pins is dead — the
/// second layer's net output never reaches the loss, so XLA strips those
/// inputs from the compiled executable).
fn init_params(hidden: usize, d_cell: usize, d_net: usize, rng: &mut Rng) -> Vec<Param> {
    let mut out = Vec::new();
    let lin = |din: usize, dout: usize, rng: &mut Rng, out: &mut Vec<Param>| {
        out.push(Param::new(Matrix::he_init(din, dout, rng)));
        out.push(Param::new(Matrix::zeros(1, dout)));
    };
    // lin_cell, lin_net
    lin(d_cell, hidden, rng, &mut out);
    lin(d_net, hidden, rng, &mut out);
    // conv1: near {w,b}, pinned {w_self,w_neigh,b}, pins {w_self,w_neigh,b}
    lin(hidden, hidden, rng, &mut out); // near w, b
    for _sage in 0..2 {
        out.push(Param::new(Matrix::he_init(hidden, hidden, rng))); // w_self
        out.push(Param::new(Matrix::he_init(hidden, hidden, rng))); // w_neigh
        out.push(Param::new(Matrix::zeros(1, hidden))); // b
    }
    // conv2: near {w,b}, pinned {w_self,w_neigh,b} (pins module is dead)
    lin(hidden, hidden, rng, &mut out);
    out.push(Param::new(Matrix::he_init(hidden, hidden, rng)));
    out.push(Param::new(Matrix::he_init(hidden, hidden, rng)));
    out.push(Param::new(Matrix::zeros(1, hidden)));
    // out head
    lin(hidden, 1, rng, &mut out);
    out
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);

    println!("== congestion_training: three-layer AOT path ==");
    let reg = ArtifactRegistry::scan(std::path::Path::new("artifacts"))?;
    let step_name = "hgnn_step_d64";
    let fwd_name = "hgnn_fwd_d64";
    anyhow::ensure!(
        reg.contains(step_name) && reg.contains(fwd_name),
        "artifacts missing — run `make artifacts` first"
    );
    let meta = reg.meta(step_name).unwrap().clone();
    let bucket_note = meta
        .notes
        .iter()
        .find(|n| n.starts_with("bucket"))
        .expect("step artifact must carry a bucket note");
    let bucket = Bucket::parse_note(bucket_note)?;
    println!("bucket: {bucket:?}");

    // --- L3: generate and pad real circuit graphs into the bucket.
    let mut rng = Rng::new(2024);
    let n_graphs = 4usize;
    let mut padded = Vec::new();
    for i in 0..n_graphs {
        let g = generate_graph(
            &GraphSpec {
                n_cells: bucket.n_cell - 16,
                n_nets: bucket.n_net - 8,
                target_near: (bucket.n_cell - 16) * 20,
                target_pins: (bucket.n_net - 8) * 2,
                d_cell: 16,
                d_net: 16,
            },
            i,
            &mut rng,
        );
        // Training must not drop edges: prefer strict padding, and fall
        // back to lossy padding loudly if the bucket is too narrow.
        let p = match pad_graph_strict(&g, bucket) {
            Ok(p) => p,
            Err(e) => {
                println!("graph {i}: strict padding rejected ({e}); falling back to lossy pad");
                pad_graph(&g, bucket)?
            }
        };
        let total_slots: usize = p.graph_tensors.iter().map(|m| m.data.len()).sum();
        println!(
            "graph {i}: {} cells, {} nets, ELL truncated {}/{} slots",
            p.real_cells, p.real_nets, p.truncated, total_slots
        );
        padded.push(p);
    }

    // --- runtime: compile the artifacts once.
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!(
                "PJRT unavailable ({e}) — this example needs the `pjrt` feature \
                 (vendor xla-rs first; see rust/Cargo.toml)"
            );
            return Ok(());
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let step_exe = rt.load_hlo_text(&reg.hlo_path(step_name))?;
    let fwd_exe = rt.load_hlo_text(&reg.hlo_path(fwd_name))?;

    // --- parameters + Adam (paper hyper-parameters).
    let mut params = init_params(bucket.hidden, 16, 16, &mut rng);
    let mut opt = Adam::new(2e-4, 1e-5);
    let n_params = params.len();
    println!(
        "model: {} tensors, {} parameters",
        n_params,
        params.iter().map(|p| p.numel()).sum::<usize>()
    );

    // Validate the feed against the artifact metadata once.
    {
        let p0 = &padded[0];
        let mut shapes: Vec<(usize, usize)> =
            params.iter().map(|p| (p.value.rows, p.value.cols)).collect();
        // Bias tensors are rank-1 in the artifact ((h,) vs rust 1×h): meta
        // validation is shape-forgiving only for exact dims, so skip the
        // strict check and rely on PJRT's own shape errors for mismatches.
        shapes.truncate(0);
        let _ = (p0, shapes);
    }

    // --- training loop: PJRT step → rust Adam.
    let mut loss_curve: Vec<(usize, f64)> = Vec::new();
    let t0 = std::time::Instant::now();
    for step in 0..steps {
        let p = &padded[step % padded.len()];
        // Feed: 22 params (+biases flattened), 12 graph tensors, feats, y, mask.
        let mut inputs: Vec<(&[f32], Vec<i64>)> = Vec::with_capacity(38);
        for (i, param) in params.iter().enumerate() {
            let dims = meta.inputs[i].1.clone();
            inputs.push((&param.value.data, dims));
        }
        for (j, m) in p.graph_tensors.iter().enumerate() {
            let dims = meta.inputs[n_params + j].1.clone();
            inputs.push((&m.data, dims));
        }
        inputs.push((&p.x_cell.data, vec![p.x_cell.rows as i64, p.x_cell.cols as i64]));
        inputs.push((&p.x_net.data, vec![p.x_net.rows as i64, p.x_net.cols as i64]));
        inputs.push((&p.y_cell.data, vec![p.y_cell.rows as i64, 1]));
        inputs.push((&p.cell_mask.data, vec![p.cell_mask.rows as i64, 1]));
        let refs: Vec<(&[f32], &[i64])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        let outputs = step_exe.run(&refs)?;
        anyhow::ensure!(outputs.len() == 1 + n_params, "expected loss + grads");
        let loss = outputs[0][0] as f64;
        // Write gradients into the Param structs and step Adam.
        for (param, grad) in params.iter_mut().zip(outputs[1..].iter()) {
            anyhow::ensure!(grad.len() == param.numel(), "gradient size mismatch");
            param.grad.data.copy_from_slice(grad);
        }
        let mut refs: Vec<&mut Param> = params.iter_mut().collect();
        opt.step(&mut refs);
        Adam::zero_grad(&mut refs);
        if step % 20 == 0 || step + 1 == steps {
            println!("step {step:4}  loss {loss:.6}");
        }
        loss_curve.push((step, loss));
    }
    let train_secs = t0.elapsed().as_secs_f64();
    let first = loss_curve.first().unwrap().1;
    let last = loss_curve.last().unwrap().1;
    println!(
        "\ntrained {steps} steps in {train_secs:.1}s ({:.1} steps/s); loss {first:.4} → {last:.4}",
        steps as f64 / train_secs
    );
    anyhow::ensure!(last < first, "loss must decrease over training");

    // --- evaluation through the inference artifact.
    let mut all_scores = Vec::new();
    for p in &padded {
        let mut inputs: Vec<(&[f32], Vec<i64>)> = Vec::with_capacity(36);
        for (i, param) in params.iter().enumerate() {
            inputs.push((&param.value.data, meta.inputs[i].1.clone()));
        }
        for (j, m) in p.graph_tensors.iter().enumerate() {
            inputs.push((&m.data, meta.inputs[n_params + j].1.clone()));
        }
        inputs.push((&p.x_cell.data, vec![p.x_cell.rows as i64, p.x_cell.cols as i64]));
        inputs.push((&p.x_net.data, vec![p.x_net.rows as i64, p.x_net.cols as i64]));
        let refs: Vec<(&[f32], &[i64])> =
            inputs.iter().map(|(d, s)| (*d, s.as_slice())).collect();
        let pred = &fwd_exe.run(&refs)?[0];
        let n = p.real_cells;
        all_scores.push(EvalScores::compute(&pred[..n], &p.y_cell.data[..n]));
    }
    let avg = EvalScores::average(&all_scores);
    println!(
        "eval (train graphs): Pearson {:.3}  Spearman {:.3}  Kendall {:.3}  MAE {:.3}  RMSE {:.3}",
        avg.pearson, avg.spearman, avg.kendall, avg.mae, avg.rmse
    );
    println!("\nOK: all three layers composed (Pallas kernels → JAX HLO → rust PJRT).");
    Ok(())
}
