//! Quickstart: generate a synthetic circuit graph, inspect its structure,
//! and run one heterogeneous message-passing layer under all three kernel
//! engines (plus the per-edge-type `"auto"` policy) — verifying the DR path
//! against the dense baseline and printing the first speedup numbers.
//!
//! Run: `cargo run --release --example quickstart`

use dr_circuitgnn::bench::{fmt_speedup, measure};
use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::{Engine, EngineBuilder};
use dr_circuitgnn::graph::stats::{degree_report, ImbalanceStats};
use dr_circuitgnn::graph::EdgeType;
use dr_circuitgnn::nn::HeteroConv;
use dr_circuitgnn::sparse::GnnaConfig;
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::math::rel_l2;
use dr_circuitgnn::util::rng::Rng;

fn main() {
    println!("== DR-CircuitGNN quickstart ==\n");

    // 1. A CircuitNet-like heterograph: cells + nets, three edge types.
    let spec = GraphSpec {
        n_cells: 2000,
        n_nets: 1000,
        target_near: 80_000,
        target_pins: 3_000,
        d_cell: 16,
        d_net: 16,
    };
    let mut rng = Rng::new(42);
    let g = generate_graph(&spec, 0, &mut rng);
    g.validate().expect("generated graph must be valid");
    println!(
        "graph: {} cells, {} nets | near {} / pins {} / pinned {} edges",
        g.n_cells,
        g.n_nets,
        g.near.nnz(),
        g.pins.nnz(),
        g.pinned.nnz()
    );
    for (edge, hist) in degree_report(&g, 4) {
        let imb = ImbalanceStats::of(g.adj(edge));
        println!(
            "  {:<7} avg deg {:6.1}  max {:4}  imbalance {:5.1}  {}",
            edge.name(),
            hist.avg_degree,
            hist.max_degree,
            imb.imbalance,
            hist.sparkline(24)
        );
    }

    // 2. One HeteroConv layer under each engine. Each `build` normalises
    //    the adjacencies and plans the kernels once (plan/execute split).
    let hidden = 64;
    let mut init_rng = Rng::new(7);
    let layer = HeteroConv::new(hidden, hidden, hidden, &mut init_rng);
    let x_cell = Matrix::randn(g.n_cells, hidden, 1.0, &mut init_rng);
    let x_net = Matrix::randn(g.n_nets, hidden, 1.0, &mut init_rng);

    let engines: [(&str, Engine); 4] = [
        ("cuSPARSE-analog", EngineBuilder::csr().build(&g)),
        ("GNNA-analog", EngineBuilder::gnna(GnnaConfig::default()).build(&g)),
        ("DR-SpMM (k=8)", EngineBuilder::dr(8, 8).build(&g)),
        ("auto", EngineBuilder::auto().k_cell(8).k_net(8).build(&g)),
    ];
    let mut baseline_t = 0.0;
    let mut baseline_out: Option<Matrix> = None;
    println!("\none HeteroConv forward (hidden {hidden}):");
    for (name, engine) in &engines {
        let stats = measure(1, 5, || {
            let mut l2 = layer.clone();
            std::hint::black_box(l2.forward(engine, &x_cell, &x_net));
        });
        let mut l = layer.clone();
        let (yc, _) = l.forward(engine, &x_cell, &x_net);
        if baseline_out.is_none() {
            baseline_t = stats.median;
            baseline_out = Some(yc.clone());
        }
        let err = rel_l2(&yc.data, &baseline_out.as_ref().unwrap().data);
        println!(
            "  {name:<16} {:8.2} ms   speedup {}   output rel-err vs dense {err:.3}",
            stats.median * 1e3,
            fmt_speedup(baseline_t, stats.median),
        );
    }
    // What did "auto" resolve to, per edge type?
    let auto_engine = &engines[3].1;
    let picks: Vec<String> = EdgeType::ALL
        .iter()
        .map(|&e| format!("{}→{}", e.name(), auto_engine.kernel_name(e)))
        .collect();
    println!("\nauto policy picks (Fig. 4 guidance): {}", picks.join("  "));
    println!(
        "\nNote: the DR path's output differs from dense by design — D-ReLU keeps\n\
         the top-k features per row (k=8 of 64 here); Fig. 10 of the paper shows\n\
         rank-correlation metrics are stable across k. Run the table2_accuracy\n\
         bench for the accuracy comparison and fig11_kernel_sweep for kernels."
    );
}
