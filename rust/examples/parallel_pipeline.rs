//! Parallel subgraph pipeline demo (paper §3.4, Fig. 9).
//!
//! Part 1 — native kernels: one end-to-end step (init + fwd + bwd per edge
//! type) under the sequential and parallel schedules, with the captured
//! lane timelines rendered like Fig. 9a/9b.
//!
//! Part 2 — fleet: the graph partitioned into independent subgraphs and a
//! full training step run across a bounded worker pool (graph-level
//! parallelism stacked on the edge lanes), with the shared plan cache and
//! the worker-count-invariant loss on display.
//!
//! Part 3 — PJRT lanes: if AOT artifacts are present, the three standalone
//! DR-SpMM executables (one per edge type) are loaded through the runtime
//! and dispatched sequentially vs from three threads — the cudaStream
//! analog at the PJRT level, proving the three-layer composition.
//!
//! Run: `cargo run --release --example parallel_pipeline [-- --fast]`

use dr_circuitgnn::datagen::{generate_graph, GraphSpec};
use dr_circuitgnn::engine::EngineBuilder;
use dr_circuitgnn::fleet::Fleet;
use dr_circuitgnn::nn::{Adam, DrCircuitGnn};
use dr_circuitgnn::runtime::{pad::to_ell, ArtifactRegistry, Runtime};
use dr_circuitgnn::sched::{run_e2e_step, ScheduleMode};
use dr_circuitgnn::tensor::Matrix;
use dr_circuitgnn::util::pool::num_threads;
use dr_circuitgnn::util::rng::Rng;
use dr_circuitgnn::util::timer::fmt_secs;

fn main() {
    let fast = std::env::args().any(|a| a == "--fast");
    let n_cells = if fast { 2_000 } else { 8_000 };
    let mut rng = Rng::new(5);
    let g = generate_graph(
        &GraphSpec {
            n_cells,
            n_nets: n_cells / 2,
            target_near: n_cells * 40,
            target_pins: (n_cells / 2) * 3,
            d_cell: 16,
            d_net: 16,
        },
        0,
        &mut rng,
    );

    println!("== Part 1: native kernel lanes (Fig. 9) ==");
    for (mode, label) in [
        (ScheduleMode::Sequential, "sequential (DGL-style, Fig. 9a)"),
        (ScheduleMode::Parallel, "parallel (3 CPU threads + lanes, Fig. 9b)"),
    ] {
        let timing = run_e2e_step(&g, 64, &EngineBuilder::dr(8, 8), mode, 3);
        println!(
            "\n{label}: total {}  busy {}  overlap ×{:.2}",
            fmt_secs(timing.total),
            fmt_secs(timing.busy),
            timing.timeline.overlap_factor()
        );
        print!("{}", timing.timeline.render(60));
    }

    println!("\n== Part 2: fleet — batched multi-subgraph training steps ==");
    let parts = 6usize;
    let fleet_graphs: Vec<_> =
        dr_circuitgnn::graph::partition::partition(&g, parts);
    let mut mrng = Rng::new(7);
    let model = DrCircuitGnn::new(g.x_cell.cols, g.x_net.cols, 32, &mut mrng);
    let mut baseline = f64::NAN;
    for workers in [1usize, num_threads().min(parts).max(2)] {
        let fleet = Fleet::builder(EngineBuilder::dr(8, 8).parallel(true))
            .workers(workers)
            .build(&fleet_graphs);
        let mut m = model.clone();
        let mut opt = Adam::new(2e-4, 1e-5);
        let t0 = std::time::Instant::now();
        let step = fleet.step(&mut m, &mut opt);
        let secs = t0.elapsed().as_secs_f64();
        if workers == 1 {
            baseline = secs;
        }
        println!(
            "{workers:>2} workers over {} subgraphs: step {}  loss {:.6}  \
             plan cache {} unique / {} lookups  speedup ×{:.2}",
            fleet.n_subgraphs(),
            fmt_secs(secs),
            step.loss,
            fleet.cache_stats().unique(),
            fleet.cache_stats().lookups(),
            baseline / secs
        );
    }
    println!("(loss is identical at every worker count — deterministic reduction)");

    println!("\n== Part 3: PJRT executable lanes ==");
    let art_dir = std::path::PathBuf::from("artifacts");
    let reg = ArtifactRegistry::scan(&art_dir).expect("scan artifacts dir");
    let names = ["spmm_near_d64", "spmm_pinned_d64", "spmm_pins_d64"];
    if !names.iter().all(|n| reg.contains(n)) {
        println!("artifacts missing — run `make artifacts` to enable the PJRT demo");
        return;
    }
    let rt = match Runtime::cpu() {
        Ok(rt) => rt,
        Err(e) => {
            println!(
                "PJRT unavailable ({e}) — Part 3 needs the `xla-backend` feature \
                 (vendor xla-rs first; see rust/Cargo.toml)"
            );
            return;
        }
    };
    println!("PJRT platform: {}", rt.platform());
    let exes: Vec<_> = names
        .iter()
        .map(|n| rt.load_hlo_text(&reg.hlo_path(n)).expect("compile artifact"))
        .collect();
    // The xla crate's executables hold non-atomic refcounts, so each
    // parallel lane gets its own client+executable — the honest analog of
    // one cudaStream (and its context) per subgraph.
    let lane_paths: Vec<_> = names.iter().map(|n| reg.hlo_path(n)).collect();

    // Bucket-shaped feeds derived from the real graph (truncated to caps).
    let (n_cell, n_net, w_near, w_pin, dim) = (256usize, 128usize, 64usize, 16usize, 64usize);
    let mut sub_rng = Rng::new(9);
    let sub = generate_graph(
        &GraphSpec {
            n_cells: n_cell,
            n_nets: n_net,
            target_near: n_cell * 24,
            target_pins: n_net * 2,
            d_cell: 16,
            d_net: 16,
        },
        0,
        &mut sub_rng,
    );
    let near_ell = to_ell(&sub.near, n_cell, w_near).unwrap();
    let pinned_ell = to_ell(&sub.pinned, n_cell, w_pin).unwrap();
    let pins_ell = to_ell(&sub.pins, n_net, w_pin).unwrap();
    let x_cell = Matrix::randn(n_cell, dim, 1.0, &mut sub_rng);
    let x_net = Matrix::randn(n_net, dim, 1.0, &mut sub_rng);
    let feeds: Vec<[&Matrix; 3]> = vec![
        [&near_ell.idx, &near_ell.val, &x_cell],
        [&pinned_ell.idx, &pinned_ell.val, &x_net],
        [&pins_ell.idx, &pins_ell.val, &x_cell],
    ];

    let reps = if fast { 5 } else { 20 };
    // Sequential dispatch.
    let t0 = std::time::Instant::now();
    for _ in 0..reps {
        for (exe, feed) in exes.iter().zip(&feeds) {
            exe.run_matrices(&feed[..]).expect("sequential run");
        }
    }
    let seq = t0.elapsed().as_secs_f64();
    // Parallel dispatch: one thread per executable (stream analog). Each
    // lane compiles its own client+executable before a barrier, so only
    // the dispatch phase is timed.
    let barrier = std::sync::Barrier::new(4);
    let t1 = std::sync::OnceLock::new();
    std::thread::scope(|s| {
        for (path, feed) in lane_paths.iter().zip(&feeds) {
            let barrier = &barrier;
            s.spawn(move || {
                let rt = Runtime::cpu().expect("lane PJRT client");
                let exe = rt.load_hlo_text(path).expect("lane compile");
                barrier.wait();
                for _ in 0..reps {
                    exe.run_matrices(&feed[..]).expect("parallel run");
                }
            });
        }
        barrier.wait();
        let _ = t1.set(std::time::Instant::now());
        // scope exit joins all lanes
    });
    let par = t1.get().unwrap().elapsed().as_secs_f64();
    println!(
        "PJRT 3-executable dispatch ×{reps}: sequential {}  parallel {}  speedup {:.2}x",
        fmt_secs(seq),
        fmt_secs(par),
        seq / par
    );
}
